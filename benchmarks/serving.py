"""Scheduler end-to-end benchmark: p50/p99 request latency under a
synthetic multi-task workload (retrieval / classification / VQA sharing
CLIP encoders), plus the queue/batch-occupancy stats that make the
simulator's batching predictions checkable against reality.

Rows feed ``benchmarks/run.py``, which also snapshots them to
``BENCH_serving.json``.
"""

from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

GB = 1024**3
TASKS = ("retrieval", "classify", "vqa")


def _deployment():
    from repro.configs.s2m3_zoo import get_clip_config
    from repro.core.cluster import ClusterSpec, DeviceSpec
    from repro.core.module import ModelSpec, ModuleSpec
    from repro.models import clip as C
    from repro.s2m3 import Deployment

    ccfg = get_clip_config("mini-clip")
    params = C.init_clip(jax.random.PRNGKey(0), ccfg)
    vis = ModuleSpec("mini-vit", "encoder", "vision", 60_000,
                     flops_per_query=2e6)
    txt = ModuleSpec("mini-trf", "encoder", "text", 50_000,
                     flops_per_query=1e6)
    w_lm = jax.random.normal(jax.random.PRNGKey(6),
                             (2 * ccfg.embed_dim, 32)) * 0.3
    builders = {
        "mini-vit": lambda: (partial(C.encode_image, cfg=ccfg),
                             params["vision"]),
        "mini-trf": lambda: (partial(C.encode_text, cfg=ccfg),
                             params["text"]),
        "cosine": lambda: (
            lambda p, enc: C.retrieval_logits(enc["vision"], enc["text"], p),
            params["logit_scale"]),
        "mini-cls": lambda: (lambda p, enc: enc["vision"] @ p,
                             jnp.ones((ccfg.embed_dim, 7))),
        "mini-lm": lambda: (
            lambda p, enc: jnp.concatenate(
                [enc["vision"], enc["text"]], -1) @ p, w_lm),
    }
    models = [
        ModelSpec("retrieval", "retrieval", (vis, txt),
                  ModuleSpec("cosine", "head", "task", 0)),
        ModelSpec("classify", "classification", (vis,),
                  ModuleSpec("mini-cls", "head", "task", 1_000,
                             flops_per_query=1e4)),
        ModelSpec("vqa", "vqa-dec", (vis, txt),
                  ModuleSpec("mini-lm", "head", "task", 80_000,
                             flops_per_query=4e6)),
    ]
    cluster = ClusterSpec(devices=[
        DeviceSpec(f"dev{i}", 1 * GB, (2.0 if i < 2 else 1.0) * 1e9)
        for i in range(4)
    ])
    dep = Deployment(cluster)
    for m in models:
        dep.add_model(m, builders)
    dep.plan("greedy", routing="queue_aware", replicate=True)
    dep.materialize()
    inputs = {
        "vision": jax.random.normal(
            jax.random.PRNGKey(1),
            (2, ccfg.n_image_tokens, ccfg.vision_width)),
        "text": jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                                   ccfg.vocab_size),
    }
    return dep, inputs


def _workload(inputs, n_requests: int):
    from repro.s2m3 import Request

    reqs = []
    for rid in range(n_requests):
        model = TASKS[rid % len(TASKS)]
        inp = dict(inputs)
        if model == "classify":
            inp = {"vision": inp["vision"]}
        reqs.append(Request(rid, model, "dev0", inputs=inp))
    return reqs


def _pct(xs, p):
    return float(np.percentile(np.asarray(xs), p))


def _decode_throughput(n_requests: int = 8, max_new: int = 8):
    """Generative tokens/sec through the paged-KV decode substrate
    (tinyllama smoke head behind ``lm_scheduler``)."""
    from repro.common.config import get_config
    from repro.core.routing import Request
    from repro.models.api import build_model
    from repro.serving.scheduler import SchedulerConfig, lm_scheduler

    cfg = get_config("tinyllama-1.1b", smoke=True)
    bundle = build_model(cfg, compute_dtype=jnp.float32)
    sched = lm_scheduler(bundle, bundle.init(jax.random.PRNGKey(0)),
                         config=SchedulerConfig(
                             decode_rows=4, page_size=8, max_seq_len=64,
                             decode_pages=33))
    reqs = [Request(rid=i, model="lm", source="dev0", prompt=(1 + i, 2, 3),
                    max_new_tokens=max_new) for i in range(n_requests)]
    sched.serve([reqs[0]])          # warm the prefill/decode compiles
    t0 = time.perf_counter()
    done = sched.serve(reqs)
    wall = time.perf_counter() - t0
    st = sched.stats_dict()[cfg.name]
    toks = sum(len(r.output) for r in done)
    return {
        "name": "paged_decode_throughput",
        "n_requests": n_requests,
        "max_new_tokens": max_new,
        "us_per_call": round(wall / max(toks, 1) * 1e6, 1),
        "wall_s": round(wall, 4),
        "decode_tokens_per_s": round(toks / wall, 1),
        "decode_steps": st["decode_steps"],
        "pages_peak": st["pages_peak"],
    }


def run(n_requests: int = 48, max_batch: int = 8):
    dep, inputs = _deployment()
    workload = _workload(inputs, n_requests)

    # warm every compiled path (solo + the batch sizes the run will see)
    for q in workload[:len(TASKS)]:
        dep.submit(q)
    dep.serve(workload, max_batch=max_batch)

    # solo baseline: one-request-at-a-time submit()
    t0 = time.perf_counter()
    solo_lat = [dep.submit(q).latency_s for q in workload]
    solo_wall = time.perf_counter() - t0

    # batched: the continuous-batching scheduler
    t0 = time.perf_counter()
    results = dep.serve(workload, max_batch=max_batch)
    serve_wall = time.perf_counter() - t0
    lat = [r.latency_s for r in results]
    stats = dep.scheduler.stats_dict()

    rows = [{
        "name": "serve_e2e",
        "n_requests": n_requests,
        "max_batch": max_batch,
        "us_per_call": round(serve_wall / n_requests * 1e6, 1),
        "p50_ms": round(_pct(lat, 50) * 1e3, 3),
        "p99_ms": round(_pct(lat, 99) * 1e3, 3),
        "wall_s": round(serve_wall, 4),
        "throughput_rps": round(n_requests / serve_wall, 1),
        "cross_task_batches": dep.scheduler.cross_task_batches,
    }, {
        "name": "solo_submit_baseline",
        "n_requests": n_requests,
        "us_per_call": round(solo_wall / n_requests * 1e6, 1),
        "p50_ms": round(_pct(solo_lat, 50) * 1e3, 3),
        "p99_ms": round(_pct(solo_lat, 99) * 1e3, 3),
        "wall_s": round(solo_wall, 4),
        "throughput_rps": round(n_requests / solo_wall, 1),
    }]
    for mod, st in stats.items():
        rows.append({"name": f"module_{mod}", **st})
    rows.append(_decode_throughput())
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
