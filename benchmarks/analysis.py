"""Analysis-pass benchmark section: wall-clock of the static verifier,
the interprocedural lockset detector, and the schedule-space model
checker.

These are the passes ``Deployment.verify()`` and ``python -m
repro.analysis --self`` put on every pre-flight and CI run, so a
slowdown here is a tax on *all* workflows.  Rows feed
``benchmarks/run.py`` → ``BENCH_analysis.json``; the ``--self`` bench
gate diffs ``wall_s`` against the snapshot and fails on a blowup, the
same tripwire the kernel and serving sections get.
"""

from __future__ import annotations

import time


def _timed(fn, *, iters: int = 3):
    """(result, median wall seconds) after one warmup call."""
    fn()
    samples = []
    result = None
    for _ in range(iters):
        t0 = time.perf_counter()
        result = fn()
        samples.append(time.perf_counter() - t0)
    samples.sort()
    return result, samples[len(samples) // 2]


def run():
    from repro.analysis import locksets, modelcheck
    from repro.analysis.concurrency_lint import lint_serving

    rows = []

    diags, wall = _timed(lint_serving)
    rows.append({
        "name": "concurrency_lint_serving",
        "wall_s": round(wall, 4),
        "us_per_call": round(wall * 1e6, 1),
        "findings": len(diags),
    })

    rep, wall = _timed(locksets.lint_serving_locksets)
    rows.append({
        "name": "lockset_serving",
        "wall_s": round(wall, 4),
        "us_per_call": round(wall * 1e6, 1),
        "contexts": rep.contexts,
        "accesses": rep.accesses,
        "findings": len(rep.diagnostics),
    })

    res, wall = _timed(
        lambda: modelcheck.check(modelcheck.default_scenario(),
                                 budget_s=60.0))
    rows.append({
        "name": "modelcheck_default",
        "wall_s": round(wall, 4),
        "us_per_call": round(wall * 1e6, 1),
        "states": res.states,
        "transitions": res.transitions,
        "states_per_s": round(res.states / wall) if wall > 0 else None,
        "complete": res.complete,
        "violation": res.counterexample is not None,
    })

    res, wall = _timed(lambda: modelcheck.self_test(budget_s=60.0))
    rows.append({
        "name": "modelcheck_self_test",
        "wall_s": round(wall, 4),
        "us_per_call": round(wall * 1e6, 1),
        "mutations": len(modelcheck.MUTATIONS),
        "findings": len(res),
    })

    return rows
