"""Per-table reproductions of the paper's experiments (Tables VI-XI).

Each function returns a list of row-dicts and is wired into
benchmarks/run.py.  The testbed is the calibrated simulator
(core/profiles.py); memory numbers are exact (published param counts),
latency numbers reproduce the paper's trends with calibration deltas
reported inline.
"""

from __future__ import annotations

from repro.core.module import distinct_modules
from repro.core.placement import (
    centralized_place, greedy_place, optimal_place,
)
from repro.core.profiles import (
    LOAD_SECONDS_PER_GB, install_profile, make_testbed,
)
from repro.core.registry import ModuleRegistry
from repro.core.routing import coalesce_batches, simulate
from repro.core.zoo import paper_zoo, request_for

ZOO = paper_zoo()
GB = 1024**3


def _cluster(with_server=True, server_gpu=True):
    c = make_testbed(with_server=with_server, server_gpu=server_gpu)
    install_profile(c, distinct_modules(list(ZOO.values())).values())
    return c


def _one(model_name, cluster, placement, requester="jetson-a"):
    reqs = [request_for(ZOO[model_name], 0, requester)]
    return simulate(reqs, placement, cluster, [ZOO[model_name]]).mean_latency


# ---------------------------------------------------------------------------
# Table VI: deployment cost + inference time per architecture
# ---------------------------------------------------------------------------

TABLE_VI_PAPER = {   # model -> (cloud_s, local_s or None, s2m3_s)
    "clip-resnet-50": (2.73, 53.23, 2.32),
    "clip-resnet-101": (2.63, 48.87, 2.39),
    "clip-resnet-50x4": (2.64, 64.54, 3.07),
    "clip-resnet-50x16": (2.65, None, 4.56),
    "clip-resnet-50x64": (2.92, None, 6.50),
    "clip-vit-b/32": (2.42, 44.26, 2.49),
    "clip-vit-b/16": (2.44, 45.19, 2.48),
    "clip-vit-l/14": (2.61, None, 4.46),
    "clip-vit-l/14@336": (2.65, None, 4.51),
    "encoder-only-vqa-s": (1.23, 6.28, 0.50),
    "encoder-only-vqa-l": (1.50, None, 1.23),
    "imagebind": (2.44, None, 2.34),
}


def table_vi():
    cluster = _cluster(with_server=True)
    edge = cluster.without("server")
    rows = []
    for name, (cloud_p, local_p, s2m3_p) in TABLE_VI_PAPER.items():
        mdl = ZOO[name]
        centralized_params = mdl.n_params
        split_params = max(m.n_params for m in mdl.modules)
        pl_cloud = centralized_place([mdl], cluster, "server")
        t_cloud = _one(name, cluster, pl_cloud)
        pl_local = centralized_place([mdl], edge, "jetson-a")
        t_local = _one(name, edge, pl_local) if pl_local.feasible else None
        pl = greedy_place([mdl], edge)
        t_s2m3 = _one(name, edge, pl) if pl.feasible else None
        rows.append({
            "model": name,
            "params_centralized_M": round(centralized_params / 1e6, 1),
            "params_s2m3_M": round(split_params / 1e6, 1),
            "split_saving_pct": round(100 * (1 - split_params
                                             / centralized_params), 1),
            "cloud_s": round(t_cloud, 2), "cloud_paper_s": cloud_p,
            "local_s": None if t_local is None else round(t_local, 2),
            "local_paper_s": local_p,
            "s2m3_s": None if t_s2m3 is None else round(t_s2m3, 2),
            "s2m3_paper_s": s2m3_p,
        })
    return rows


# ---------------------------------------------------------------------------
# Table VII: deployment comparison for CLIP ViT-B/16 (+ end-to-end w/ load)
# ---------------------------------------------------------------------------

def table_vii():
    rows = []
    clip = ZOO["clip-vit-b/16"]
    fp32_bytes = clip.n_params * 4          # paper deploys fp32 checkpoints
    load_all = fp32_bytes / GB * LOAD_SECONDS_PER_GB

    for label, with_server, gpu, dev, paper in [
        ("server", True, True, "server", 2.44),
        ("server-nogpu", True, False, "server-nogpu", 6.70),
        ("desktop", False, None, "desktop", 3.46),
        ("laptop", False, None, "laptop", 3.02),
        ("jetson", False, None, "jetson-a", 45.19),
    ]:
        cluster = _cluster(with_server=with_server, server_gpu=bool(gpu))
        pl = centralized_place([clip], cluster, dev)
        t = _one("clip-vit-b/16", cluster, pl)
        rows.append({"deployment": f"centralized-{label}",
                     "inference_s": round(t, 2), "paper_s": paper,
                     "end_to_end_s": round(t + load_all, 2)})

    edge = _cluster(with_server=False)
    pl = greedy_place([clip], edge)
    t = _one("clip-vit-b/16", edge, pl)
    biggest = max(m.n_params for m in clip.modules) * 4 / GB
    rows.append({"deployment": "s2m3", "inference_s": round(t, 2),
                 "paper_s": 2.48,
                 "end_to_end_s": round(t + biggest * LOAD_SECONDS_PER_GB, 2)})

    # w/o parallel processing: encoders serialized on their devices
    from repro.core.routing import work_multiplier

    dev_of = {m.name: pl.assignment[m.name][0] for m in clip.modules}
    req = request_for(clip, 0, "jetson-a")
    t_serial = sum(
        edge.comp_table[(m.name, dev_of[m.name])]
        * work_multiplier(req, m.modality, edge.device(dev_of[m.name]))
        for m in clip.encoders) + 0.05
    rows.append({"deployment": "s2m3-no-parallel",
                 "inference_s": round(t_serial, 2), "paper_s": 3.03,
                 "end_to_end_s": None})
    return rows


# ---------------------------------------------------------------------------
# Table IX: device availability
# ---------------------------------------------------------------------------

def table_ix():
    clip = ZOO["clip-vit-b/16"]
    rows = []
    scenarios = [
        ("jetson-only", ["desktop", "laptop", "jetson-b", "server"], 45.19),
        ("j-a+j-b", ["desktop", "laptop", "server"], 42.70),
        ("j+laptop+j-b", ["desktop", "server"], 2.49),
        ("all-edge", ["server"], 2.48),
        ("all+server", [], 1.74),
    ]
    for label, removed, paper in scenarios:
        cluster = _cluster(with_server=True).without(*removed)
        pl = greedy_place([clip], cluster)
        t = _one("clip-vit-b/16", cluster, pl) if pl.feasible else None
        rows.append({"scenario": label,
                     "latency_s": None if t is None else round(t, 2),
                     "paper_s": paper})
    return rows


# ---------------------------------------------------------------------------
# Table X: multi-task sharing (cost + latency under 4 simultaneous tasks)
# ---------------------------------------------------------------------------

TABLE_X_TASKS = ["clip-vit-b/16", "encoder-only-vqa-s", "alignment-vit-b",
                 "clip-cls-vit-b/16"]


def table_x():
    rows = []
    cluster = _cluster(with_server=False)
    reg = ModuleRegistry()
    models = []
    for i, name in enumerate(TABLE_X_TASKS):
        models.append(ZOO[name])
        reg.add_model(ZOO[name])
        reqs = [request_for(m, j, "jetson-a") for j, m in enumerate(models)]

        pl_shared = greedy_place(models, cluster, share=True)
        t_shared = simulate(reqs, pl_shared, cluster, models).max_latency

        pl_sep = greedy_place(models, cluster, share=False)
        t_sep = simulate(reqs, pl_sep, cluster, models).max_latency \
            if pl_sep.feasible else None

        dedicated = sum(m.n_params for m in models)
        rows.append({
            "tasks": i + 1, "added": name,
            "params_shared_M": round(reg.shared_bytes() / 4 / 1e6, 0),
            "params_dedicated_M": round(dedicated / 1e6, 0),
            "sharing_saving_pct": round(100 * reg.sharing_savings(), 1),
            "latency_shared_s": round(t_shared, 2),
            "latency_dedicated_s": None if t_sep is None else round(t_sep, 2),
        })
    return rows


# ---------------------------------------------------------------------------
# Table XI: baselines (Optimus / DistMM tensor-parallel ideal, Megatron)
# ---------------------------------------------------------------------------

def table_xi():
    """Baselines per the paper's own protocol (footnote 3): TP latency is
    the ideal compute time divided across the device pool, Megatron-LM is
    per-module model parallelism without cross-encoder parallelism."""
    cluster = _cluster(with_server=False)
    n_dev = len(cluster.devices)
    speed_sum = sum(d.compute_speed for d in cluster.devices)
    rows = []

    cases = {
        "vqa": ("flint-v0.5-1b", 1.57, None),
        "retrieval": ("clip-vit-b/16", None, 2.48),
        "alignment": ("alignment-vit-b", None, None),
    }
    for task, (name, opt_paper, distmm_paper) in cases.items():
        mdl = ZOO[name]
        pl = greedy_place([mdl], cluster)
        t_s2m3 = _one(name, cluster, pl)
        work = dict(request_for(mdl, 0, "jetson-a").work)
        # TP-ideal: all module flops spread across aggregate pool speed
        from repro.core.profiles import KIND_SPEED

        t_tp = sum(
            m.flops_per_query * work.get(m.modality, 1.0)
            / (speed_sum * KIND_SPEED.get(m.modality, 1.0))
            for m in mdl.modules)
        # Megatron-style: same module-level split, but encoders serialized
        dev_of = {m.name: pl.assignment[m.name][0] for m in mdl.modules}
        t_mega = sum(cluster.comp_table[(m.name, dev_of[m.name])]
                     * work.get(m.modality, 1.0)
                     for m in mdl.modules)
        rows.append({
            "task": task, "model": name,
            "tp_ideal_s": round(t_tp, 2),
            "optimus_paper_s": opt_paper, "distmm_paper_s": distmm_paper,
            "megatron_s": round(t_mega, 2),
            "s2m3_s": round(t_s2m3, 2),
            "params_s2m3_M": round(ZOO[name].n_params / 1e6, 0),
        })
    return rows


# ---------------------------------------------------------------------------
# batching discussion (§VI-C)
# ---------------------------------------------------------------------------

def batching():
    cluster = _cluster(with_server=False)
    clip = ZOO["clip-vit-b/16"]
    pl = greedy_place([clip], cluster)
    reqs = [request_for(clip, i, "jetson-a") for i in range(10)]
    t_seq = simulate(reqs, pl, cluster, [clip]).max_latency
    merged = coalesce_batches(reqs, window=1.0)
    t_batched = simulate(merged, pl, cluster, [clip]).max_latency
    return [{"requests": 10, "sequential_makespan_s": round(t_seq, 2),
             "batched_makespan_s": round(t_batched, 2),
             "speedup": round(t_seq / t_batched, 2)}]
