"""Benchmark driver: one section per paper table + roofline + microbench
+ the continuous-batching scheduler.

Prints ``name,us_per_call,derived`` CSV rows (per the harness contract):
simulator latencies are reported in us; `derived` carries the row's full
dict for human inspection.  Alongside the CSV, every section's rows are
snapshotted to ``BENCH_<section>.json`` at the repo root so perf claims
(kernel us/call, simulator latencies, scheduler end-to-end p50/p99) are
diffable against history.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

SNAPSHOT_DIR = Path(__file__).resolve().parents[1]


def _emit(name: str, us, derived):
    d = json.dumps(derived, default=str).replace(",", ";")
    print(f"{name},{us},{d}")


def _snapshot(section: str, rows, error: str | None = None) -> None:
    from benchmarks.diff import machine_profile

    path = SNAPSHOT_DIR / f"BENCH_{section}.json"
    # the machine header lets diff.py refuse cross-machine comparisons:
    # wall-clocks only mean something against a baseline from this box
    payload = {"section": section, "machine": machine_profile(),
               "rows": rows}
    if error is not None:
        payload["error"] = error
    path.write_text(json.dumps(payload, indent=2, default=str) + "\n")


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    from benchmarks import (
        analysis, kernels, microbench, optimality, roofline, serving,
        tables,
    )

    sections = {
        "table_vi": tables.table_vi,
        "table_vii": tables.table_vii,
        "table_ix": tables.table_ix,
        "table_x": tables.table_x,
        "table_xi": tables.table_xi,
        "batching": tables.batching,
        "optimality_89_of_95": lambda: optimality.run(95),
        "roofline": roofline.rows,
        "roofline_summary": roofline.summary,
        "microbench": microbench.run,
        "serving": serving.run,
        "kernels": kernels.run,
        "analysis": analysis.run,
    }
    print("name,us_per_call,derived")
    for name, fn in sections.items():
        if only and only != name:
            continue
        try:
            rows = fn()
        except Exception as e:  # report, keep the harness going
            err = f"{type(e).__name__}: {e}"
            _emit(name, "", {"error": err})
            _snapshot(name, [], error=err)
            continue
        for i, row in enumerate(rows):
            us = row.get("us_per_call")
            if us is None:
                for key in ("s2m3_s", "latency_s", "inference_s",
                            "latency_shared_s", "roofline_s", "t_compute_s"):
                    if row.get(key) is not None:
                        us = round(float(row[key]) * 1e6, 1)
                        break
            _emit(f"{name}[{i}]", "" if us is None else us, row)
        _snapshot(name, list(rows))


if __name__ == "__main__":
    main()
