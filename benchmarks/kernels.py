"""Kernel microbenchmark section: us/call for every Pallas entry point
in interpret mode (this box is CPU-only; TPU is the compile target), at
CPU-sized shapes.

Interpret-mode timings track Python-level kernel-body cost, not Mosaic
performance — their value here is as a *regression tripwire*: a kernel
edit that doubles the interpret-mode time almost certainly grew the real
working set too.  Rows feed ``benchmarks/run.py``, which snapshots them
to ``BENCH_kernels.json`` for ``python -m repro.analysis --self`` to
diff against.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *, iters: int = 3) -> float:
    """Median wall-clock seconds per call (after one warmup)."""
    jax.block_until_ready(fn())
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples))


def _cases():
    from repro.kernels import ops

    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)

    # flash_attention: (B, S, H, D) prefill-style
    B, S, H, D = 2, 256, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    yield ("flash_attention", {"B": B, "S": S, "H": H, "D": D},
           lambda: ops.flash_attention(q, k, v, causal=True,
                                       block_q=128, block_k=128,
                                       interpret=True))

    # decode_attention: single query over a contiguous cache
    T = 512
    dq = jax.random.normal(ks[3], (B, H, D), jnp.float32)
    dk = jax.random.normal(ks[4], (B, T, H, D), jnp.float32)
    dv = jax.random.normal(ks[5], (B, T, H, D), jnp.float32)
    dlen = jnp.array([T, T // 2], jnp.int32)
    yield ("decode_attention", {"B": B, "T": T, "H": H, "D": D},
           lambda: ops.decode_attention(dq, dk, dv, dlen, block_k=256,
                                        interpret=True))

    # paged_decode_attention: same workload through the page pool
    page_size = 16
    n_max = -(-T // page_size)
    n_pages = B * n_max + 1
    perm = np.random.default_rng(0).permutation(n_pages - 1) + 1
    tables = np.asarray(perm[:B * n_max].reshape(B, n_max), np.int32)
    kp = np.zeros((n_pages, page_size, H, D), np.float32)
    vp = np.zeros((n_pages, page_size, H, D), np.float32)
    for b in range(B):
        for j in range(n_max):
            sl = np.asarray(dk[b, j * page_size:(j + 1) * page_size])
            kp[tables[b, j], :sl.shape[0]] = sl
            vp[tables[b, j], :sl.shape[0]] = np.asarray(
                dv[b, j * page_size:(j + 1) * page_size])
    kp, vp = jnp.asarray(kp), jnp.asarray(vp)
    jtables = jnp.asarray(tables)
    yield ("paged_decode_attention",
           {"B": B, "T": T, "H": H, "D": D, "page_size": page_size},
           lambda: ops.paged_decode_attention(dq, kp, vp, jtables, dlen,
                                              interpret=True))

    # ssd_chunked: Mamba2 SSD scan
    Bs, Ss, Hs, P, N = 1, 256, 2, 32, 16
    x = jax.random.normal(ks[6], (Bs, Ss, Hs, P), jnp.float32)
    Bm = jax.random.normal(ks[7], (Bs, Ss, N), jnp.float32) * 0.1
    Cm = jax.random.normal(ks[0], (Bs, Ss, N), jnp.float32) * 0.1
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bs, Ss, Hs)))
    A_log = jnp.zeros((Hs,))
    yield ("ssd_chunked", {"B": Bs, "S": Ss, "H": Hs, "P": P, "N": N},
           lambda: ops.ssd_chunked(x, Bm, Cm, dt, A_log, chunk=64,
                                   interpret=True))

    # slstm_scan: recurrent sLSTM cell sweep
    Bg, Sg, Hh, hd = 2, 128, 4, 16
    pre = jax.random.normal(ks[2], (Bg, Sg, 4, Hh * hd), jnp.float32) * 0.5
    R = jax.random.normal(ks[3], (4, Hh, hd, hd), jnp.float32) * 0.2
    yield ("slstm_scan", {"B": Bg, "S": Sg, "H": Hh, "hd": hd},
           lambda: ops.slstm_scan(pre, R, block_s=64, interpret=True))


def run():
    rows = []
    for name, dims, fn in _cases():
        sec = _time(fn)
        rows.append({"name": name, "us_per_call": round(sec * 1e6, 1),
                     "interpret": True, **dims})
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
