"""Compare two ``BENCH_<section>.json`` snapshots and flag regressions.

The bench driver (``benchmarks/run.py``) snapshots every section's rows
to the repo root; this tool diffs two such snapshots — typically the
committed baseline vs a fresh run — and reports every latency metric
that regressed beyond a threshold ratio::

    python benchmarks/diff.py BENCH_serving.baseline.json BENCH_serving.json
    python benchmarks/diff.py old.json new.json --threshold 1.10

Rows are matched by their ``name`` field (falling back to list position
for unnamed rows); the compared metrics are the latency-bearing keys
(``p50_ms``, ``p99_ms``, ``us_per_call``, ``wall_s``, ``latency_s``).
Exit status 1 when any regression exceeds the threshold, so the diff
can gate CI.  Lower is better for every compared metric; improvements
and new/removed rows are reported but never fail the run.
"""

from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass
from pathlib import Path

#: metrics compared between snapshots — all latencies, lower is better
METRICS = ("p50_ms", "p99_ms", "us_per_call", "wall_s", "latency_s")

DEFAULT_THRESHOLD = 1.20     # flag when new > old * threshold


@dataclass(frozen=True)
class Regression:
    row: str
    metric: str
    old: float
    new: float

    @property
    def ratio(self) -> float:
        return self.new / self.old if self.old else float("inf")

    def format(self) -> str:
        return (f"REGRESSION {self.row}.{self.metric}: "
                f"{self.old:g} -> {self.new:g} ({self.ratio:.2f}x)")


def _rows_by_name(snapshot: dict) -> dict[str, dict]:
    out: dict[str, dict] = {}
    for i, row in enumerate(snapshot.get("rows", [])):
        key = str(row.get("name", f"row[{i}]"))
        if key in out:                      # duplicate names: positional
            key = f"{key}[{i}]"
        out[key] = row
    return out


def diff_snapshots(old: dict, new: dict, *,
                   threshold: float = DEFAULT_THRESHOLD
                   ) -> tuple[list[Regression], list[str]]:
    """Returns (regressions beyond ``threshold``, informational notes:
    improvements, added/removed rows, metric coverage changes)."""
    old_rows, new_rows = _rows_by_name(old), _rows_by_name(new)
    regressions: list[Regression] = []
    notes: list[str] = []
    for name in sorted(old_rows.keys() | new_rows.keys()):
        if name not in new_rows:
            notes.append(f"row {name!r} removed in new snapshot")
            continue
        if name not in old_rows:
            notes.append(f"row {name!r} added in new snapshot")
            continue
        o, n = old_rows[name], new_rows[name]
        for metric in METRICS:
            ov, nv = o.get(metric), n.get(metric)
            if ov is None or nv is None:
                if (ov is None) != (nv is None):
                    notes.append(
                        f"{name}.{metric} present in only one snapshot")
                continue
            ov, nv = float(ov), float(nv)
            if ov > 0 and nv > ov * threshold:
                regressions.append(Regression(name, metric, ov, nv))
            elif nv > 0 and ov > nv * threshold:
                notes.append(f"improvement {name}.{metric}: "
                             f"{ov:g} -> {nv:g}")
    return regressions, notes


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH_<section>.json snapshots; exit 1 on "
                    "latency regressions beyond --threshold")
    ap.add_argument("old", type=Path, help="baseline snapshot")
    ap.add_argument("new", type=Path, help="candidate snapshot")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="regression ratio (default %(default)s = +20%%)")
    args = ap.parse_args(argv)

    old = json.loads(args.old.read_text())
    new = json.loads(args.new.read_text())
    if old.get("section") != new.get("section"):
        print(f"note: comparing different sections "
              f"{old.get('section')!r} vs {new.get('section')!r}")
    regressions, notes = diff_snapshots(old, new,
                                        threshold=args.threshold)
    for note in notes:
        print(note)
    for r in regressions:
        print(r.format())
    print(f"{len(regressions)} regression(s) beyond "
          f"{args.threshold:.2f}x, {len(notes)} note(s)")
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
