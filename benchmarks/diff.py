"""Compare two ``BENCH_<section>.json`` snapshots and flag regressions.

The bench driver (``benchmarks/run.py``) snapshots every section's rows
to the repo root; this tool diffs two such snapshots — typically the
committed baseline vs a fresh run — and reports every latency metric
that regressed beyond a threshold ratio::

    python benchmarks/diff.py BENCH_serving.baseline.json BENCH_serving.json
    python benchmarks/diff.py old.json new.json --threshold 1.10

Rows are matched by their ``name`` field (falling back to list position
for unnamed rows); the compared metrics are the latency-bearing keys
(``p50_ms``, ``p99_ms``, ``us_per_call``, ``wall_s``, ``latency_s``).
Exit status 1 when any regression exceeds the threshold, so the diff
can gate CI.  Lower is better for every compared metric; improvements
and new/removed rows are reported but never fail the run.

Snapshots carry a ``machine`` profile header (``machine_profile()``,
stamped by ``benchmarks/run.py``): platform, python/jax versions, jax
backend and device kind/count.  Wall-clock latencies are only
comparable on the same machine, so the diff *refuses* cross-machine
comparisons (exit 2) unless ``--ignore-machine`` is given; missing
files, unreadable JSON, mismatched sections, and disjoint row sets also
exit 2 with a one-line explanation instead of a traceback.
"""

from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass
from pathlib import Path

#: metrics compared between snapshots — all latencies, lower is better
METRICS = ("p50_ms", "p99_ms", "us_per_call", "wall_s", "latency_s")

DEFAULT_THRESHOLD = 1.20     # flag when new > old * threshold


def machine_profile() -> dict:
    """Where these wall-clocks were measured: enough to tell whether two
    snapshots are comparable at all."""
    import platform

    prof = {
        "platform": platform.platform(),
        "machine": platform.machine(),
        "python": platform.python_version(),
    }
    try:
        import jax

        prof["jax"] = jax.__version__
        prof["backend"] = jax.default_backend()
        devs = jax.devices()
        prof["device_kind"] = devs[0].device_kind if devs else "none"
        prof["device_count"] = len(devs)
    except Exception:                      # no jax / no backend: still a
        prof["jax"] = "unavailable"        # usable (cpu-side) profile
    return prof


def profile_mismatches(old: dict | None, new: dict | None) -> list[str]:
    """Human-readable differences between two machine profiles.  A
    snapshot without a profile header is never comparable (regenerate it
    with benchmarks/run.py)."""
    if not old or not new:
        which = ("both snapshots" if not old and not new
                 else "baseline snapshot" if not old
                 else "candidate snapshot")
        return [f"{which} carry no machine profile header"]
    out = []
    for key in sorted(set(old) | set(new)):
        ov, nv = old.get(key), new.get(key)
        if ov != nv:
            out.append(f"{key}: {ov!r} vs {nv!r}")
    return out


@dataclass(frozen=True)
class Regression:
    row: str
    metric: str
    old: float
    new: float

    @property
    def ratio(self) -> float:
        return self.new / self.old if self.old else float("inf")

    def format(self) -> str:
        return (f"REGRESSION {self.row}.{self.metric}: "
                f"{self.old:g} -> {self.new:g} ({self.ratio:.2f}x)")


def _rows_by_name(snapshot: dict) -> dict[str, dict]:
    out: dict[str, dict] = {}
    for i, row in enumerate(snapshot.get("rows", [])):
        key = str(row.get("name", f"row[{i}]"))
        if key in out:                      # duplicate names: positional
            key = f"{key}[{i}]"
        out[key] = row
    return out


def diff_snapshots(old: dict, new: dict, *,
                   threshold: float = DEFAULT_THRESHOLD
                   ) -> tuple[list[Regression], list[str]]:
    """Returns (regressions beyond ``threshold``, informational notes:
    improvements, added/removed rows, metric coverage changes)."""
    old_rows, new_rows = _rows_by_name(old), _rows_by_name(new)
    regressions: list[Regression] = []
    notes: list[str] = []
    for name in sorted(old_rows.keys() | new_rows.keys()):
        if name not in new_rows:
            notes.append(f"row {name!r} removed in new snapshot")
            continue
        if name not in old_rows:
            notes.append(f"row {name!r} added in new snapshot")
            continue
        o, n = old_rows[name], new_rows[name]
        for metric in METRICS:
            ov, nv = o.get(metric), n.get(metric)
            if ov is None or nv is None:
                if (ov is None) != (nv is None):
                    notes.append(
                        f"{name}.{metric} present in only one snapshot")
                continue
            ov, nv = float(ov), float(nv)
            if ov > 0 and nv > ov * threshold:
                regressions.append(Regression(name, metric, ov, nv))
            elif nv > 0 and ov > nv * threshold:
                notes.append(f"improvement {name}.{metric}: "
                             f"{ov:g} -> {nv:g}")
    return regressions, notes


def _load_snapshot(path: Path, role: str) -> dict | None:
    """Read one snapshot, reporting problems as one-line messages
    (never a traceback): missing file, unreadable JSON, wrong shape."""
    if not path.exists():
        print(f"error: {role} snapshot {path} does not exist "
              "(run benchmarks/run.py to produce it)")
        return None
    try:
        snap = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: {role} snapshot {path} is not readable JSON: {e}")
        return None
    if not isinstance(snap, dict) or not isinstance(snap.get("rows", []),
                                                    list):
        print(f"error: {role} snapshot {path} is not a BENCH_<section> "
              "snapshot (expected an object with 'section' and 'rows')")
        return None
    return snap


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH_<section>.json snapshots; exit 1 on "
                    "latency regressions beyond --threshold, 2 when the "
                    "snapshots are not comparable")
    ap.add_argument("old", type=Path, help="baseline snapshot")
    ap.add_argument("new", type=Path, help="candidate snapshot")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="regression ratio (default %(default)s = +20%%)")
    ap.add_argument("--ignore-machine", action="store_true",
                    help="compare even when the machine profile headers "
                         "differ (wall-clock ratios will be meaningless)")
    args = ap.parse_args(argv)

    old = _load_snapshot(args.old, "baseline")
    new = _load_snapshot(args.new, "candidate")
    if old is None or new is None:
        return 2
    if old.get("section") != new.get("section"):
        print(f"error: section mismatch: {args.old} is "
              f"{old.get('section')!r} but {args.new} is "
              f"{new.get('section')!r} — compare like with like")
        return 2
    mismatches = profile_mismatches(old.get("machine"), new.get("machine"))
    if mismatches:
        for m in mismatches:
            print(f"machine profile: {m}")
        if not args.ignore_machine:
            print("refusing cross-machine comparison: wall-clock "
                  "latencies are only comparable on the machine that "
                  "recorded the baseline (re-run benchmarks/run.py here, "
                  "or pass --ignore-machine)")
            return 2
    if old.get("rows") and new.get("rows") \
            and not (_rows_by_name(old).keys() & _rows_by_name(new).keys()):
        print("error: the snapshots share no row names — nothing to "
              "compare")
        return 2
    regressions, notes = diff_snapshots(old, new,
                                        threshold=args.threshold)
    for note in notes:
        print(note)
    for r in regressions:
        print(r.format())
    print(f"{len(regressions)} regression(s) beyond "
          f"{args.threshold:.2f}x, {len(notes)} note(s)")
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
