"""Wall-clock microbenchmarks of the real compute paths (CPU, small
shapes): reported as us_per_call so regressions are visible."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=5):
    fn(*args)                       # compile + warm
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def run():
    from repro.common.config import get_config
    from repro.core.routing import Request
    from repro.models.api import build_model
    from repro.serving.scheduler import SchedulerConfig, lm_scheduler

    rows = []
    cfg = get_config("tinyllama-1.1b", smoke=True)
    bundle = build_model(cfg, compute_dtype=jnp.float32)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.zeros((2, 32), jnp.int32),
        "targets": jnp.zeros((2, 32), jnp.int32),
        "mask": jnp.ones((2, 32), jnp.float32),
    }
    loss = jax.jit(lambda p, b: bundle.loss_fn(p, b)[0])
    rows.append({"name": "loss_fwd_tinyllama_smoke",
                 "us_per_call": round(_time(loss, params, batch), 1)})

    grad = jax.jit(jax.grad(lambda p, b: bundle.loss_fn(p, b)[0]))
    rows.append({"name": "grad_tinyllama_smoke",
                 "us_per_call": round(_time(grad, params, batch), 1)})

    cache = bundle.init_cache(2, 64, dtype=jnp.float32)
    dec = jax.jit(bundle.decode_step)
    toks = jnp.zeros((2, 1), jnp.int32)
    lens = jnp.full((2,), 8, jnp.int32)
    rows.append({"name": "decode_step_tinyllama_smoke",
                 "us_per_call": round(_time(dec, params, toks, cache, lens), 1)})

    # serving throughput through the paged decode substrate
    sched = lm_scheduler(bundle, params, config=SchedulerConfig(
        decode_rows=4, page_size=8, max_seq_len=64, decode_pages=33))
    reqs = [Request(rid=i, model="lm", source="dev0", prompt=(1, 2, 3),
                    max_new_tokens=8) for i in range(8)]
    t0 = time.perf_counter()
    done = sched.serve(reqs)
    dt = time.perf_counter() - t0
    toks_out = sum(len(r.output) for r in done)
    rows.append({"name": "server_tokens_per_s",
                 "us_per_call": round(dt / max(toks_out, 1) * 1e6, 1),
                 "derived": f"{toks_out / dt:.1f} tok/s"})

    # kernels (interpret mode)
    from repro.kernels import ops

    q = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 2, 16))
    rows.append({
        "name": "flash_attention_interpret_64",
        "us_per_call": round(_time(
            lambda: ops.flash_attention(q, k, v, block_q=32, block_k=32,
                                        interpret=True)), 1)})
    return rows
