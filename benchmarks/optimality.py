"""Greedy-vs-optimal rate: the paper's 89/95 (93.7%) claim.

95 instances = random samples over (model combo, requester, device
availability, request count); each instance is planned through the
``s2m3.Deployment`` facade with the ``greedy`` and ``optimal`` placement
strategies, and we count exact matches (within float tolerance).
"""

from __future__ import annotations

import random

from repro.core.module import distinct_modules
from repro.core.profiles import install_profile, make_testbed
from repro.core.zoo import paper_zoo, request_for
from repro.s2m3 import Deployment

SMALL_MODELS = [
    "clip-resnet-50", "clip-resnet-101", "clip-vit-b/32", "clip-vit-b/16",
    "clip-vit-l/14", "encoder-only-vqa-s", "encoder-only-vqa-l",
    "alignment-vit-b", "clip-cls-vit-b/16", "nlp-connect",
]


def run(n_instances: int = 95, seed: int = 0):
    zoo = paper_zoo()
    rng = random.Random(seed)
    matches, total, ratios = 0, 0, []
    for i in range(n_instances):
        name = rng.choice(SMALL_MODELS)
        mdl = zoo[name]
        cluster = make_testbed(with_server=rng.random() < 0.3)
        # random availability: drop up to one device
        if rng.random() < 0.4 and len(cluster.devices) > 2:
            cluster = cluster.without(rng.choice(cluster.devices).name)
        install_profile(cluster, distinct_modules([mdl]).values())
        requester = rng.choice(cluster.devices).name
        # the paper's protocol: 19 (benchmark x model) combos x 5 trials,
        # one inference request per trial
        reqs = [request_for(mdl, 0, requester)]
        dep = Deployment(cluster).add_model(mdl)
        dep.plan("greedy", routing="paper")
        if not dep.placement.feasible:
            continue
        t_g = dep.simulate(reqs).total_latency
        t_o = dep.plan("optimal", routing="paper",
                       workload=reqs).simulate(reqs).total_latency
        total += 1
        ratios.append(t_g / t_o if t_o > 0 else 1.0)
        if t_g <= t_o * 1.001:
            matches += 1
    within5 = sum(1 for r in ratios if r <= 1.05)
    return [{
        "instances": total,
        # exact match under a NOISELESS simulator (the paper's 89/95 is
        # under wall-clock measurement noise; 5 trials averaged)
        "optimal_matches_exact": matches,
        "match_rate_exact_pct": round(100 * matches / max(total, 1), 1),
        "matches_within_5pct": within5,
        "match_rate_5pct": round(100 * within5 / max(total, 1), 1),
        "paper_rate_pct": 93.7,
        "mean_ratio_to_optimal": round(sum(ratios) / max(len(ratios), 1), 4),
        "worst_ratio": round(max(ratios, default=1.0), 4),
    }]
