"""Roofline table: read the dry-run artifacts and emit §Roofline rows."""

from __future__ import annotations

import json
import pathlib

REPO = pathlib.Path(__file__).resolve().parents[1]
ART = REPO / "results" / "dryrun"


def rows(mesh: str = "pod16x16", include_variants: bool = False):
    out = []
    if not ART.exists():
        return out
    for f in sorted(ART.glob(f"*__{mesh}*.json")):
        parts = f.stem.split("__")
        if len(parts) == 4 and not include_variants:
            continue
        data = json.loads(f.read_text())
        if "skipped" in data:
            out.append({"arch": data["arch"], "shape": data["shape"],
                        "mesh": data["mesh"], "skipped": data["skipped"]})
            continue
        r = data["roofline"]
        out.append({
            "arch": data["arch"], "shape": data["shape"],
            "mesh": data["mesh"],
            "variant": data.get("perf_variant", "baseline"),
            "t_compute_s": round(r["t_compute_s"], 4),
            "t_memory_s": round(r["t_memory_s"], 4),
            "t_collective_s": round(r["t_collective_s"], 4),
            "dominant": r["dominant"],
            "compute_fraction": round(r["compute_fraction"], 4),
            "hbm_per_device_gib": data["hbm_per_device_gib"],
            "model_vs_hlo_flops": (None if data.get("model_vs_hlo_flops")
                                   is None
                                   else round(data["model_vs_hlo_flops"], 3)),
            "compile_s": data.get("compile_s"),
        })
    return out


def summary():
    rs = [r for r in rows() if "skipped" not in r]
    if not rs:
        return [{"note": "no dry-run artifacts yet; run "
                 "`python -m repro.launch.dryrun --all`"}]
    dominant = {}
    for r in rs:
        dominant[r["dominant"]] = dominant.get(r["dominant"], 0) + 1
    worst = min(rs, key=lambda r: r["compute_fraction"])
    return [{
        "cells": len(rs),
        "dominant_counts": dominant,
        "worst_cell": f"{worst['arch']}/{worst['shape']}",
        "worst_compute_fraction": worst["compute_fraction"],
    }]
