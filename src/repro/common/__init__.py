"""Shared substrate: configs, sharding rules, pytree helpers, roofline math."""
