"""Architecture / shape / run configuration.

``ArchConfig`` is pure data covering all assigned families (dense, MoE,
MLA+MoE, VLM, audio enc-dec, Mamba2 hybrid, xLSTM).  ``models/api.py``
interprets it into concrete stage lists.  Config files in
``repro/configs/`` register instances under their ``--arch`` id.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    experts_top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim (d_ff used if 0)
    first_dense_layers: int = 0      # leading dense blocks (deepseek: 3)
    expert_pad_to: int = 0           # pad expert count for EP divisibility
    router_aux_loss: float = 0.0

    # --- MLA (deepseek) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # --- attention variants ---
    sliding_window: int = 0          # window size for "local" layers
    attn_pattern: tuple[str, ...] = ()   # e.g. ("local", "global") alternation
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    rope_theta: float = 10000.0
    use_rope: bool = True
    sub_quadratic: bool = False      # eligible for long_500k
    dense_d_ff: int = 0              # dense-layer FFN width when != d_ff (deepseek)

    # --- SSM / hybrid ---
    ssm_state: int = 0
    mamba_head_dim: int = 64
    mamba_expand: int = 2
    mamba_conv_width: int = 4
    mamba_chunk: int = 128
    n_mamba_per_super: int = 0       # zamba2: mamba blocks per shared-attn call
    shared_attn_d_ff: int = 0        # zamba2 shared block MLP width

    # --- xLSTM ---
    mlstm_to_slstm: int = 0          # e.g. 7 => groups of 7 mLSTM + 1 sLSTM
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 1.3334
    xlstm_chunk: int = 128
    # unrolling the sLSTM time scan lets XLA CSE the recurrent-weight reads
    # across steps: HBM traffic of R drops by the unroll factor (§Perf)
    slstm_unroll: int = 1

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500          # precomputed audio frames (frontend stub)

    # --- VLM ---
    has_vision_stub: bool = False
    n_image_tokens: int = 256        # precomputed patch embeddings (stub)

    # --- misc ---
    act_fn: str = "silu"             # silu | gelu | gelu_tanh
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    embed_scale_by_dim: bool = False  # gemma: embeds *= sqrt(d_model)
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    post_norm: bool = False          # gemma2 uses pre+post norms
    mtp_depth: int = 0               # deepseek multi-token-prediction heads

    # --- sharding: per-shape-kind logical rule overrides ---
    sharding_overrides: Mapping[str, Mapping[str, Any]] = field(default_factory=dict)

    # shapes to skip entirely (e.g. long_500k for quadratic attention)
    skip_shapes: tuple[str, ...] = ()
    skip_reason: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    def with_overrides(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    @property
    def q_per_kv(self) -> int:
        return max(1, self.n_heads // max(1, self.n_kv_heads))


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    moment_dtype: str = "float32"    # "bfloat16" for the 405B/671B fit
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"              # none | full | dots
    microbatches: int = 1
    z_loss: float = 0.0
    grad_compression: str = "none"   # none | int8
    seed: int = 0


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], ArchConfig]] = {}
_SMOKE: dict[str, Callable[[], ArchConfig]] = {}


def register_arch(name: str, full: Callable[[], ArchConfig], smoke: Callable[[], ArchConfig]):
    _REGISTRY[name] = full
    _SMOKE[name] = smoke


def get_config(name: str, *, smoke: bool = False) -> ArchConfig:
    import repro.configs  # noqa: F401  (triggers registration)

    table = _SMOKE if smoke else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(table)}")
    return table[name]()


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)
