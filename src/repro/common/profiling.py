"""Extract roofline inputs from a lowered/compiled XLA program.

``cost_analysis()`` gives FLOPs and HBM traffic; collective bytes are NOT
reported there, so we parse the (optimized, partitioned) HLO text and sum
the operand sizes of every collective op.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g. "bf16[2048,1024]{1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# HLO instruction line: "%name = <shape-or-tuple> op-name(...)"
_INSTR_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^=]*?)\s+(" + "|".join(COLLECTIVE_OPS) + r")(-start|-done)?\("
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: dict[str, int] = field(default_factory=dict)
    count_by_op: dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum output-shape bytes of every collective in (optimized) HLO text.

    We count each logical collective once: the async "-start" op is counted,
    the matching "-done" is skipped; synchronous forms are counted directly.
    Output shape is used as the byte proxy (for all-gather it's the gathered
    size, for reduce-scatter the scattered size, both reasonable one-pass
    traffic proxies at the per-device level).
    """
    stats = CollectiveStats()
    for m in _INSTR_RE.finditer(hlo_text):
        shape_txt, op, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue
        b = _shape_bytes(shape_txt)
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + b
        stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
    return stats


def cost_summary(compiled) -> dict:
    """Pull flops/bytes out of compiled.cost_analysis() across jax versions."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    # bytes accessed may be split across keys depending on version
    byts = float(ca.get("bytes accessed", 0.0))
    if byts == 0.0:
        byts = sum(float(v) for k, v in ca.items() if k.startswith("bytes accessed"))
    return {"flops": flops, "bytes": byts, "raw_keys": sorted(ca)[:8]}


def memory_summary(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        if hasattr(ma, k):
            out[k] = int(getattr(ma, k))
    out["total_bytes"] = (
        out.get("argument_size_in_bytes", 0)
        + out.get("output_size_in_bytes", 0)
        + out.get("temp_size_in_bytes", 0)
        - out.get("alias_size_in_bytes", 0)
    )
    return out


def model_flops_per_step(n_params_active: float, tokens: float) -> float:
    """Standard 6·N·D estimate (training). For inference use 2·N·D."""
    return 6.0 * n_params_active * tokens
