"""Pytree helpers: counting, casting, flattened paths."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def param_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def param_bytes(tree) -> int:
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree.leaves(tree)
    )


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def tree_paths(tree) -> dict[str, object]:
    """Flatten to {'a/b/c': leaf} using dict keys as path components."""
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out[key] = leaf
    return out


def tree_allclose(a, b, rtol=1e-5, atol=1e-5) -> bool:
    leaves_a, leaves_b = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(leaves_a) != len(leaves_b):
        return False
    return all(
        np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol)
        for x, y in zip(leaves_a, leaves_b)
    )


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))
