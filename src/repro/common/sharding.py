"""Logical-axis sharding rules.

Every weight / activation dimension carries a *logical* axis name
("embed", "mlp", "heads", "batch", ...).  A rule table maps logical names
to mesh axis names.  ``spec_for`` resolves a logical-axis tuple into a
``PartitionSpec``, demoting any mesh axis whose size does not divide the
corresponding dimension (demotion = replication: always correct, possibly
wasteful — the roofline report surfaces the waste).

This is the single knob surface for the perf hillclimb: a sharding
*profile* is just a rule-table override.
"""

from __future__ import annotations

import math
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Mesh axes in this codebase: ("pod", "data", "model") multi-pod,
# ("data", "model") single pod.
MeshAxes = tuple[str, ...] | str | None

# Default rules: FSDP over (pod, data) for the embed dim, tensor
# parallelism over "model" for heads / mlp / vocab / experts, batch data-
# parallel over (pod, data), decode KV cache sequence-sharded over "model".
DEFAULT_RULES: dict[str, MeshAxes] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "act_embed": None,
    "act_heads": "model",
    "act_mlp": "model",
    "vocab_out": "model",
    # weights
    "embed": ("pod", "data"),     # FSDP axis
    "mlp": "model",
    "heads": "model",
    "qkv_features": "model",
    "kv_heads": "model",
    "vocab": "model",
    "experts": "model",
    "expert_mlp": None,
    "layers": None,
    "norm": None,
    "mla_rank": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "ssm_heads": "model",
    "slstm_rec": None,
    # kv cache
    "cache_batch": ("pod", "data"),
    "cache_seq": "model",
    "cache_heads": None,
    "cache_feat": None,
    # optimizer
    "replicated": None,
}


def merge_rules(*overrides: Mapping[str, MeshAxes] | None) -> dict[str, MeshAxes]:
    rules = dict(DEFAULT_RULES)
    for ov in overrides:
        if ov:
            rules.update(ov)
    return rules


def _axes_present(entry: MeshAxes, mesh: Mesh) -> tuple[str, ...]:
    if entry is None:
        return ()
    if isinstance(entry, str):
        entry = (entry,)
    return tuple(a for a in entry if a in mesh.shape)


def spec_for(
    shape: Sequence[int],
    logical_axes: Sequence[str | None],
    rules: Mapping[str, MeshAxes],
    mesh: Mesh,
) -> P:
    """Resolve logical axes into a PartitionSpec valid for `shape` on `mesh`.

    Per-dimension, mesh axes are kept only while the running product still
    divides the dimension size (prefix demotion), and an axis is never used
    twice in one spec.
    """
    assert len(shape) == len(logical_axes), (shape, logical_axes)
    used: set[str] = set()
    out: list = []
    for dim, name in zip(shape, logical_axes):
        if name is None:
            out.append(None)
            continue
        entry = rules.get(name, None)
        axes = _axes_present(entry, mesh)
        kept: list[str] = []
        prod = 1
        for a in axes:
            if a in used:
                continue
            sz = mesh.shape[a]
            if dim % (prod * sz) == 0:
                kept.append(a)
                prod *= sz
        used.update(kept)
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    return P(*out)


def sharding_for(shape, logical_axes, rules, mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, logical_axes, rules, mesh))


def tree_pspecs(spec_tree, rules, mesh: Mesh):
    """Map a WSpec pytree (see layers.initializers) to PartitionSpecs."""
    from repro.layers.initializers import WSpec  # local import, avoids cycle

    def one(ws):
        if isinstance(ws, WSpec):
            return spec_for(ws.shape, ws.axes, rules, mesh)
        raise TypeError(f"expected WSpec, got {type(ws)}")

    return jax.tree.map(one, spec_tree, is_leaf=lambda x: isinstance(x, WSpec))


def tree_shardings(spec_tree, rules, mesh: Mesh):
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p),
        tree_pspecs(spec_tree, rules, mesh),
        is_leaf=lambda x: isinstance(x, P),
    )


def local_mesh(shape: tuple[int, ...] = (1, 1), axes: tuple[str, ...] = ("data", "model")) -> Mesh:
    """A trivial mesh on the current devices — used by smoke tests/benches."""
    devs = jax.devices()[: math.prod(shape)]
    import numpy as np

    return Mesh(np.asarray(devs).reshape(shape), axes)
