"""Trip-count-aware cost analysis over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
lax.scan-over-layers program under-reports flops/bytes/collectives by
the trip count.  Fully unrolling for the dry-run is not compileable for
the 126-layer x 512-device giants (>30 min), so instead we parse the
optimized HLO: every while op carries ``backend_config=
{"known_trip_count":{"n":...}}`` and we multiply callee costs through
the call graph (fusion/call/while/conditional).

Counted:
* flops      — MXU work: dot ops (2 * prod(out) * contracted), the
               roofline-relevant number (elementwise flops excluded —
               they ride the memory term);
* bytes      — traffic model: per (post-fusion) instruction, operand
               bytes + output bytes, fusions opaque (their internal
               traffic is on-chip by construction);
* collectives — output-shape bytes per op kind, async -start counted
               once, with loop multipliers applied.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"\b([a-z][a-z0-9\-]*)\(")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_RE = re.compile(
    r"(?:calls|to_apply|body|condition|true_computation|false_computation)="
    r"%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_list(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _shape_list(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    op: str
    out_text: str
    rest: str

    @property
    def out_bytes(self) -> int:
        return _shape_bytes(self.out_text)


@dataclass
class CostReport:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    bytes_by_op: dict = field(default_factory=dict)
    count_by_op: dict = field(default_factory=dict)
    dot_flops_by_shape: dict = field(default_factory=dict)
    n_whiles: int = 0


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
}


class HloCost:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[Instr]] = {}
        self._parse(hlo_text)
        self.entry = self._entry_name(hlo_text)
        self._memo_flops: dict[str, float] = {}
        self._memo_bytes: dict[str, float] = {}
        self._memo_coll: dict[str, dict] = {}

    # -- parsing ----------------------------------------------------------
    def _parse(self, text: str):
        cur: list[Instr] | None = None
        cur_name = None
        for line in text.splitlines():
            if cur is None:
                m = _COMP_HEADER_RE.match(line.strip())
                if m and line.rstrip().endswith("{"):
                    cur_name = m.group(1)
                    cur = []
                continue
            if line.strip() == "}":
                self.comps[cur_name] = cur
                cur = None
                continue
            m = _ASSIGN_RE.match(line)
            if m:
                rhs = m.group(2)
                mo = _OP_RE.search(rhs)
                if mo:
                    cur.append(Instr(m.group(1), mo.group(1),
                                     rhs[: mo.start()], rhs[mo.end():]))

    def _entry_name(self, text: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        return m.group(1) if m else next(iter(self.comps))

    # -- shape map ---------------------------------------------------------
    def _shape_map(self, comp: str) -> dict[str, str]:
        return {i.name: i.out_text for i in self.comps.get(comp, [])}

    def _trip(self, ins: Instr) -> int:
        m = _TRIP_RE.search(ins.rest)
        return int(m.group(1)) if m else 1

    def _callees(self, ins: Instr) -> list[tuple[str, float]]:
        """(computation, multiplier) call edges of one instruction."""
        out = []
        if ins.op == "while":
            trip = self._trip(ins)
            for kind, name in re.findall(
                    r"(body|condition)=%?([\w.\-]+)", ins.rest):
                out.append((name, float(trip) if kind == "body" else 1.0))
            return out
        if ins.op in ("fusion", "call", "map", "reduce", "reduce-window",
                      "scatter", "sort", "conditional", "custom-call",
                      "select-and-scatter", "all-reduce", "reduce-scatter"):
            for name in _CALLED_RE.findall(ins.rest):
                out.append((name, 1.0))
            m = _BRANCHES_RE.search(ins.rest)
            if m:
                for name in _OPERAND_RE.findall(m.group(1)):
                    out.append((name, 1.0))
        return out

    # -- flops --------------------------------------------------------------
    def _dot_flops(self, ins: Instr, shapes: dict[str, str]) -> float:
        out_elems = 1
        for _, dims in _shape_list(ins.out_text):
            for d in dims:
                out_elems *= d
        # contracted extent from lhs shape + contracting dims
        ops = _OPERAND_RE.findall(ins.rest.split(")")[0])
        cd = _CDIMS_RE.search(ins.rest)
        contracted = 1
        if ops and cd and ops[0] in shapes:
            lhs = _shape_list(shapes[ops[0]])
            if lhs:
                dims = lhs[0][1]
                for idx in (int(x) for x in cd.group(1).split(",") if x):
                    if idx < len(dims):
                        contracted *= dims[idx]
        return 2.0 * out_elems * contracted

    def flops(self, comp: str | None = None) -> float:
        comp = comp or self.entry
        if comp in self._memo_flops:
            return self._memo_flops[comp]
        self._memo_flops[comp] = 0.0   # cycle guard
        shapes = self._shape_map(comp)
        total = 0.0
        for ins in self.comps.get(comp, []):
            if ins.op == "dot":
                total += self._dot_flops(ins, shapes)
            elif ins.op == "convolution":
                total += 2.0 * _shape_bytes(ins.out_text)   # rough; unused
            for callee, mult in self._callees(ins):
                total += mult * self.flops(callee)
        self._memo_flops[comp] = total
        return total

    # -- bytes ---------------------------------------------------------------
    def bytes(self, comp: str | None = None) -> float:
        comp = comp or self.entry
        if comp in self._memo_bytes:
            return self._memo_bytes[comp]
        self._memo_bytes[comp] = 0.0
        shapes = self._shape_map(comp)
        total = 0.0
        for ins in self.comps.get(comp, []):
            if ins.op not in _SKIP_BYTES_OPS:
                total += ins.out_bytes
                for op_name in _OPERAND_RE.findall(ins.rest.split(")")[0]):
                    total += _shape_bytes(shapes.get(op_name, ""))
            for callee, mult in self._callees(ins):
                if ins.op in ("while", "call", "conditional"):
                    total += mult * self.bytes(callee)
        self._memo_bytes[comp] = total
        return total

    # -- collectives -----------------------------------------------------------
    def collectives(self, comp: str | None = None) -> dict:
        comp = comp or self.entry
        if comp in self._memo_coll:
            return self._memo_coll[comp]
        self._memo_coll[comp] = {"bytes_by_op": {}, "count_by_op": {}}
        bb, cb = {}, {}
        for ins in self.comps.get(comp, []):
            base = ins.op.removesuffix("-start")
            if base in COLLECTIVE_OPS and not ins.op.endswith("-done"):
                bb[base] = bb.get(base, 0.0) + ins.out_bytes
                cb[base] = cb.get(base, 0.0) + 1
            for callee, mult in self._callees(ins):
                sub = self.collectives(callee)
                for k, v in sub["bytes_by_op"].items():
                    bb[k] = bb.get(k, 0.0) + mult * v
                for k, v in sub["count_by_op"].items():
                    cb[k] = cb.get(k, 0.0) + mult * v
        out = {"bytes_by_op": bb, "count_by_op": cb}
        self._memo_coll[comp] = out
        return out

    def report(self) -> CostReport:
        coll = self.collectives()
        return CostReport(
            flops=self.flops(),
            bytes=self.bytes(),
            collective_bytes=sum(coll["bytes_by_op"].values()),
            bytes_by_op=coll["bytes_by_op"],
            count_by_op=coll["count_by_op"],
        )


def analyze(hlo_text: str) -> CostReport:
    return HloCost(hlo_text).report()
