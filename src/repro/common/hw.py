"""Hardware constants for roofline analysis.

Target hardware is TPU v5e (per the assignment): these constants are the
denominators of the three roofline terms.  The testbed simulator
(core/profiles.py) carries its own per-device constants for the paper's
edge hardware.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float     # FLOP/s per chip
    hbm_bandwidth: float       # bytes/s per chip
    hbm_bytes: float           # HBM capacity per chip
    ici_bandwidth: float       # bytes/s per link
    ici_links: int             # links per chip (2D torus: 4)


TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,    # per assignment: 197 TFLOP/s bf16
    hbm_bandwidth=819e9,       # 819 GB/s
    hbm_bytes=16 * 1024**3,    # 16 GiB
    ici_bandwidth=50e9,        # ~50 GB/s per link (assignment constant)
    ici_links=4,
)

DEFAULT_CHIP = TPU_V5E


def roofline_terms(
    hlo_flops: float,
    hlo_bytes: float,
    collective_bytes: float,
    n_chips: int,
    chip: ChipSpec = DEFAULT_CHIP,
    *,
    per_device: bool = True,
) -> dict:
    """The three roofline terms in seconds.

    ``per_device=True`` means the flops/bytes arguments were measured on the
    partitioned (per-device) HLO module, which is what
    ``compiled.cost_analysis()`` reports for an SPMD program; we therefore do
    NOT divide by n_chips again.  Set ``per_device=False`` for whole-program
    numbers.
    """
    div = 1.0 if per_device else float(n_chips)
    t_comp = hlo_flops / div / chip.peak_flops_bf16
    t_mem = hlo_bytes / div / chip.hbm_bandwidth
    # Collectives move bytes over ICI; a chip in a 2D/3D torus drives
    # ici_links links.  We charge collective bytes against the aggregate
    # per-chip link bandwidth: conservative for ring-scheduled collectives.
    t_coll = collective_bytes / div / (chip.ici_bandwidth * chip.ici_links)
    dominant = max(
        (("compute", t_comp), ("memory", t_mem), ("collective", t_coll)),
        key=lambda kv: kv[1],
    )[0]
    bound = max(t_comp, t_mem, t_coll)
    return {
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "roofline_s": bound,
        "compute_fraction": (t_comp / bound) if bound > 0 else 0.0,
    }
