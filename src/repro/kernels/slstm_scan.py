"""sLSTM recurrence kernel: recurrent weights resident in VMEM.

The §Perf xlstm hillclimb measured the pure-XLA sLSTM spending ~1.65 PB
per device per step re-reading the 67 MB recurrent matrices on each of
24,576 scan steps.  This kernel holds R (and the running state) in VMEM
scratch and streams only the precomputed gate pre-activations through —
the HBM traffic drops to the gate streams themselves.

Grid = (B_blocks, S_blocks); the sequence dimension is minor-most
(sequential on TPU) so the (c, n, h, m) state scratch carries across
sequence blocks.  Inside a block a fori_loop steps the exact xLSTM
equations (exp gating + stabilizer), with the per-head block-diagonal
recurrent matmul unrolled over the (few) heads.

Cell contract (matches layers.xlstm.slstm_apply's inner scan):
  gi = pre_i[t] + h R_i ;  gf = pre_f[t] + h R_f
  gz = tanh(pre_z[t] + h R_z) ;  go = sigmoid(pre_o[t] + h R_o)
  m' = max(logsigmoid(gf) + m, gi)
  c  = exp(logsigmoid(gf) + m - m') c + exp(gi - m') gz
  n  = exp(logsigmoid(gf) + m - m') n + exp(gi - m')
  h  = go * c / max(n, 1e-6)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.plan import KernelPlanError, slstm_block_plan

GATES = ("i", "f", "z", "o")


def _kernel(pre_ref, r_ref, o_ref, c_ref, n_ref, h_ref, m_ref, *,
            bs, n_heads, hd, d):
    sb = pl.program_id(1)

    @pl.when(sb == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.full_like(n_ref, 1e-6)
        h_ref[...] = jnp.zeros_like(h_ref)
        m_ref[...] = jnp.zeros_like(m_ref)

    pre = pre_ref[0].astype(jnp.float32)          # (bs, 4, d)
    R = r_ref[...].astype(jnp.float32)            # (4, H, hd, hd)

    def step(t, _):
        c = c_ref[...]
        n = n_ref[...]
        h = h_ref[...]
        m = m_ref[...]
        hh = h.reshape(n_heads, hd)
        rec = []
        for g in range(4):
            # block-diagonal recurrent matmul, unrolled over heads
            parts = [
                jax.lax.dot_general(
                    hh[hd_i][None, :], R[g, hd_i],
                    (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)[0]
                for hd_i in range(n_heads)
            ]
            rec.append(jnp.concatenate(parts))
        gi = pre[t, 0] + rec[0]
        gf = pre[t, 1] + rec[1]
        gz = jnp.tanh(pre[t, 2] + rec[2])
        go = jax.nn.sigmoid(pre[t, 3] + rec[3])
        logf = jax.nn.log_sigmoid(gf)
        m_new = jnp.maximum(logf + m, gi)
        fp = jnp.exp(logf + m - m_new)
        ip = jnp.exp(gi - m_new)
        c_new = fp * c + ip * gz
        n_new = fp * n + ip
        h_new = go * c_new / jnp.maximum(n_new, 1e-6)
        c_ref[...] = c_new
        n_ref[...] = n_new
        h_ref[...] = h_new
        m_ref[...] = m_new
        o_ref[0, t] = h_new.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, bs, step, 0, unroll=False)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def slstm_scan(pre, R, *, block_s: int = 128, interpret: bool = False):
    """pre: (B, S, 4, d) gate pre-activations (Wx + b, gate order i,f,z,o);
    R: (4, H, hd, hd) block-diagonal recurrent weights.  Returns h (B,S,d).

    One batch row per program (grid dim 0); VMEM footprint = R + one
    (block_s, 4, d) gate tile + 4 state vectors.
    """
    B, S, four, d = pre.shape
    if four != 4:
        raise KernelPlanError(
            f"slstm_scan: pre must carry the 4 gates (i,f,z,o) on axis 2, "
            f"got {four}")
    _, H, hd, _ = R.shape
    plan = slstm_block_plan(B, S, d, H, hd, block_s, pre.dtype)
    bs, n_sb = plan.meta["bs"], plan.meta["n_sb"]

    kernel = functools.partial(_kernel, bs=bs, n_heads=H, hd=hd, d=d)
    out = pl.pallas_call(
        kernel,
        grid=(B, n_sb),
        in_specs=[
            pl.BlockSpec((1, bs, 4, d), lambda b, sb: (b, sb, 0, 0)),
            pl.BlockSpec((4, H, hd, hd), lambda b, sb: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, d), lambda b, sb: (b, sb, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, d), pre.dtype),
        scratch_shapes=[
            pltpu.VMEM((d,), jnp.float32),
            pltpu.VMEM((d,), jnp.float32),
            pltpu.VMEM((d,), jnp.float32),
            pltpu.VMEM((d,), jnp.float32),
        ],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(pre, R)
    return out


def _compiler_params():
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams")
    return cls(dimension_semantics=("parallel", "arbitrary"))
