"""Pallas TPU kernels for the compute hot spots.

flash_attention — fused streaming-softmax attention (train/prefill);
decode_attention — single-query attention over a long KV cache;
ssd_scan — Mamba2 intra-chunk SSD block.

Each kernel ships with a jit wrapper (ops.py) and a pure-jnp oracle
(ref.py); tests sweep shapes/dtypes in interpret=True mode (this box is
CPU-only; TPU is the compile target).
"""
