"""Static block plans for the Pallas kernels.

Each kernel's grid / BlockSpec geometry is derived here by a pure
function of the operand shapes, so it can be computed (and validated)
in two places with one source of truth:

* the kernel wrappers call their ``*_block_plan`` at trace time —
  invalid geometry raises ``KernelPlanError`` with a fix hint instead
  of a bare ``assert``;
* ``repro.analysis.kernel_check`` calls the same functions to vet the
  whole zoo's shapes statically, with no device execution.

The VMEM estimate follows the TPU model in the Pallas guide: blocks
live in ~16 MiB of VMEM per core, tiles are padded to (sublane, 128)
where the sublane count is 8/16/32 for 4/2/1-byte dtypes, and streamed
operands are double-buffered (x2); grid-invariant (resident) operands
and scratch count once.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

VMEM_BYTES = 16 * 1024 * 1024          # per-core VMEM (v4/v5 ballpark)


class KernelPlanError(ValueError):
    """Kernel geometry is invalid for the given shapes (grid/BlockSpec
    divisibility, head folding, gate layout)."""


@dataclass(frozen=True)
class BlockPlan:
    kernel: str
    grid: tuple[int, ...]
    blocks: dict[str, tuple[int, ...]] = field(default_factory=dict)
    vmem_bytes: int = 0
    meta: dict[str, int] = field(default_factory=dict)


def _itemsize(dtype) -> int:
    try:
        import numpy as np

        return int(np.dtype(str(dtype).replace("bfloat16", "float16")
                            ).itemsize)
    except Exception:
        return 4


def tile_padded_bytes(shape: tuple[int, ...], dtype) -> int:
    """Bytes of one VMEM-resident block, padded to the dtype's native
    (sublane, 128) tile."""
    isz = _itemsize(dtype)
    sublane = max(8, 32 // isz)
    dims = [d for d in shape if d > 1] or [1]
    if len(dims) == 1:
        dims = [1, dims[0]]
    lead = math.prod(dims[:-2])
    rows = -(-dims[-2] // sublane) * sublane
    cols = -(-dims[-1] // 128) * 128
    return lead * rows * cols * isz


def _vmem(streamed: dict[str, tuple[tuple[int, ...], object]],
          resident: dict[str, tuple[tuple[int, ...], object]]) -> int:
    total = 0
    for shape, dtype in streamed.values():
        total += 2 * tile_padded_bytes(shape, dtype)
    for shape, dtype in resident.values():
        total += tile_padded_bytes(shape, dtype)
    return total


def _check_divides(total: int, block: int, dim: str, knob: str,
                   kernel: str) -> None:
    if total % block:
        raise KernelPlanError(
            f"{kernel}: {dim}={total} is not a multiple of the "
            f"{knob}={block} block; pad {dim} or pass a {knob} that "
            f"divides it")


def flash_block_plan(B: int, S: int, H: int, D: int, T: int, K: int,
                     block_q: int, block_k: int, dtype) -> BlockPlan:
    """Geometry for ``flash_attention``: grid (B*H, S/bq, T/bk)."""
    if K <= 0 or H % K:
        raise KernelPlanError(
            f"flash_attention: q heads H={H} must be a multiple of kv "
            f"heads K={K} (GQA folding)")
    bq, bk = min(block_q, S), min(block_k, T)
    _check_divides(S, bq, "S", "block_q", "flash_attention")
    _check_divides(T, bk, "T", "block_k", "flash_attention")
    f32 = "float32"
    return BlockPlan(
        kernel="flash_attention",
        grid=(B * H, S // bq, T // bk),
        blocks={"q": (1, bq, D), "k": (1, bk, 1, D), "v": (1, bk, 1, D),
                "o": (1, bq, D)},
        vmem_bytes=_vmem(
            streamed={"q": ((1, bq, D), dtype), "k": ((1, bk, 1, D), dtype),
                      "v": ((1, bk, 1, D), dtype), "o": ((1, bq, D), dtype)},
            resident={"m": ((bq,), f32), "l": ((bq,), f32),
                      "acc": ((bq, D), f32), "scores": ((bq, bk), f32)}),
        meta={"bq": bq, "bk": bk, "n_kv": T // bk, "G": H // K})


def decode_block_plan(B: int, H: int, D: int, T: int, K: int,
                      block_k: int, dtype) -> BlockPlan:
    """Geometry for ``decode_attention``: grid (B*H, T/bk)."""
    if K <= 0 or H % K:
        raise KernelPlanError(
            f"decode_attention: q heads H={H} must be a multiple of kv "
            f"heads K={K} (GQA folding)")
    bk = min(block_k, T)
    _check_divides(T, bk, "T", "block_k", "decode_attention")
    f32 = "float32"
    return BlockPlan(
        kernel="decode_attention",
        grid=(B * H, T // bk),
        blocks={"q": (1, 1, D), "k": (1, bk, 1, D), "v": (1, bk, 1, D),
                "o": (1, 1, D)},
        vmem_bytes=_vmem(
            streamed={"q": ((1, 1, D), dtype), "k": ((1, bk, 1, D), dtype),
                      "v": ((1, bk, 1, D), dtype), "o": ((1, 1, D), dtype)},
            resident={"m": ((1,), f32), "l": ((1,), f32),
                      "acc": ((1, D), f32), "scores": ((1, bk), f32)}),
        meta={"bk": bk, "n_kv": T // bk, "G": H // K})


def paged_decode_block_plan(B: int, H: int, D: int, page_size: int,
                            n_max: int, n_pages: int, K: int,
                            dtype) -> BlockPlan:
    """Geometry for ``paged_decode_attention``: grid (B*H, n_max).

    The KV cache is a global pool of ``n_pages`` fixed-size pages
    (page_size, K, D); each program's j-th step DMAs the page named by
    the scalar-prefetched block table entry ``table[b, j]`` — the page
    gather happens in the BlockSpec index_map, so the kernel body is the
    same streaming softmax as ``decode_attention`` with bk=page_size.
    """
    if K <= 0 or H % K:
        raise KernelPlanError(
            f"paged_decode_attention: q heads H={H} must be a multiple "
            f"of kv heads K={K} (GQA folding)")
    if page_size < 1 or n_max < 1 or n_pages < 1:
        raise KernelPlanError(
            f"paged_decode_attention: page_size={page_size}, "
            f"n_max={n_max}, n_pages={n_pages} must all be >= 1")
    if n_pages < n_max:
        raise KernelPlanError(
            f"paged_decode_attention: a single sequence's block table "
            f"has n_max={n_max} entries but the pool only holds "
            f"n_pages={n_pages} pages; shrink max_seq_len/page count "
            "mismatch or grow the pool")
    ps = page_size
    f32 = "float32"
    return BlockPlan(
        kernel="paged_decode_attention",
        grid=(B * H, n_max),
        blocks={"q": (1, 1, D), "k": (1, ps, 1, D), "v": (1, ps, 1, D),
                "o": (1, 1, D)},
        vmem_bytes=_vmem(
            streamed={"q": ((1, 1, D), dtype), "k": ((1, ps, 1, D), dtype),
                      "v": ((1, ps, 1, D), dtype), "o": ((1, 1, D), dtype)},
            resident={"m": ((1,), f32), "l": ((1,), f32),
                      "acc": ((1, D), f32), "scores": ((1, ps), f32)}),
        meta={"ps": ps, "n_max": n_max, "n_pages": n_pages, "G": H // K})


def ssd_block_plan(B: int, S: int, H: int, P: int, N: int,
                   chunk: int, dtype) -> BlockPlan:
    """Geometry for ``ssd_chunked`` / ``ssd_intra_chunk``: one
    (batch, chunk, head) program holding the (L, L) score tile."""
    L = min(chunk, S)
    _check_divides(S, L, "S", "chunk", "ssd_chunked")
    nc = S // L
    f32 = "float32"
    return BlockPlan(
        kernel="ssd_scan",
        grid=(B * nc, 1, H),
        blocks={"x": (1, 1, 1, L, P), "B": (1, 1, L, N), "C": (1, 1, L, N),
                "dt": (1, 1, 1, L, 1), "y": (1, 1, 1, L, P),
                "s": (1, 1, 1, N, P)},
        vmem_bytes=_vmem(
            streamed={"x": ((L, P), dtype), "B": ((L, N), dtype),
                      "C": ((L, N), dtype), "dt": ((L, 1), dtype),
                      "y": ((L, P), f32), "s": ((N, P), f32)},
            # G, decay and the masked score matrix M all materialize at
            # (L, L) fp32 inside the program
            resident={"M3": ((3 * L, L), f32)}),
        meta={"L": L, "nc": nc})


def slstm_block_plan(B: int, S: int, d: int, H: int, hd: int,
                     block_s: int, dtype) -> BlockPlan:
    """Geometry for ``slstm_scan``: recurrent weights resident in VMEM,
    gate pre-activations streamed in (block_s, 4, d) tiles."""
    if H * hd != d:
        raise KernelPlanError(
            f"slstm_scan: n_heads*head_dim = {H}*{hd} != d={d} "
            "(block-diagonal recurrence needs exact head folding)")
    bs = min(block_s, S)
    _check_divides(S, bs, "S", "block_s", "slstm_scan")
    f32 = "float32"
    return BlockPlan(
        kernel="slstm_scan",
        grid=(B, S // bs),
        blocks={"pre": (1, bs, 4, d), "R": (4, H, hd, hd),
                "o": (1, bs, d)},
        vmem_bytes=_vmem(
            streamed={"pre": ((1, bs, 4, d), dtype),
                      "o": ((1, bs, d), dtype)},
            # R's index map is grid-invariant: one resident copy
            resident={"R": ((4, H, hd, hd), dtype),
                      "state": ((4, d), f32)}),
        meta={"bs": bs, "n_sb": S // bs})
