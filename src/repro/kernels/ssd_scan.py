"""Mamba2 SSD intra-chunk kernel.

Per (batch, chunk, head) program: builds the causal decay-weighted score
matrix M[t,s] = C_t·B_s · exp(cum_t - cum_s) · dt_s in VMEM, produces
the intra-chunk output Y = M @ X and the chunk's outgoing state
S_loc = Σ_s exp(cum_L - cum_s)·dt_s·(B_s ⊗ x_s) — the two quantities the
host-level associative scan (inter-chunk) consumes.  This is the tile
the pure-XLA path materializes at (B, nc, L, L, H) fp32; the kernel
keeps it at (L, L) per program in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, B_ref, C_ref, dt_ref, alog_ref, y_ref, s_ref, *, L):
    h = pl.program_id(2)
    x = x_ref[0, 0, 0].astype(jnp.float32)     # (L, P)
    Bm = B_ref[0, 0].astype(jnp.float32)       # (L, N)
    Cm = C_ref[0, 0].astype(jnp.float32)       # (L, N)
    dt = dt_ref[0, 0, 0, :, 0].astype(jnp.float32)  # (L,)
    a = -jnp.exp(alog_ref[h].astype(jnp.float32))  # scalar

    dA = dt * a                                 # (L,) log decays
    cum = jnp.cumsum(dA)                        # (L,)

    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L,L) t,s
    decay = jnp.exp(cum[:, None] - cum[None, :])
    t_pos = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    s_pos = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    M = jnp.where(s_pos <= t_pos, G * decay * dt[None, :], 0.0)

    y_ref[0, 0, 0] = jax.lax.dot_general(
        M, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)

    w_end = jnp.exp(cum[-1] - cum) * dt         # (L,)
    s_ref[0, 0, 0] = jax.lax.dot_general(
        Bm * w_end[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(s_ref.dtype)  # (N, P)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(x, Bm, Cm, dt, A_log, *, interpret: bool = False):
    """x: (B,nc,L,H,P); Bm/Cm: (B,nc,L,N); dt: (B,nc,L,H) post-softplus.

    Returns (y_intra (B,nc,L,H,P) f32, S_loc (B,nc,H,N,P) f32,
             Lam (B,nc,H) f32 chunk decay) — inputs to the host-level
    inter-chunk associative scan.
    """
    B, nc, L, H, P = x.shape
    N = Bm.shape[-1]

    xt = x.transpose(0, 1, 3, 2, 4)            # (B,nc,H,L,P)
    dtt = dt.transpose(0, 1, 3, 2)[..., None]  # (B,nc,H,L,1)

    kernel = functools.partial(_kernel, L=L)
    y, s_loc = pl.pallas_call(
        kernel,
        grid=(B * nc, 1, H),
        in_specs=[
            pl.BlockSpec((1, 1, 1, L, P),
                         lambda bc, _, h, nc=nc: (bc // nc, bc % nc, h, 0, 0)),
            pl.BlockSpec((1, 1, L, N),
                         lambda bc, _, h, nc=nc: (bc // nc, bc % nc, 0, 0)),
            pl.BlockSpec((1, 1, L, N),
                         lambda bc, _, h, nc=nc: (bc // nc, bc % nc, 0, 0)),
            pl.BlockSpec((1, 1, 1, L, 1),
                         lambda bc, _, h, nc=nc: (bc // nc, bc % nc, h, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, L, P),
                         lambda bc, _, h, nc=nc: (bc // nc, bc % nc, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, N, P),
                         lambda bc, _, h, nc=nc: (bc // nc, bc % nc, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, H, L, P), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, H, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(xt.reshape(B, nc, H, L, P), Bm, Cm, dtt.reshape(B, nc, H, L, 1), A_log)

    dA = dt.astype(jnp.float32) * (-jnp.exp(A_log.astype(jnp.float32)))
    Lam = jnp.exp(dA.sum(axis=2))              # (B,nc,H)
    return y.transpose(0, 1, 3, 2, 4), s_loc, Lam


def ssd_chunked(x, Bm, Cm, dt, A_log, *, initial_state=None,
                interpret: bool = False):
    """Full SSD: Pallas intra-chunk + jnp inter-chunk associative scan.

    Same contract as kernels.ref.ssd_chunk_ref but chunked inputs:
    x (B,nc,L,H,P) etc.  Returns (y (B,nc,L,H,P), final (B,H,N,P)).
    """
    B, nc, L, H, P = x.shape
    y_intra, S_loc, Lam = ssd_intra_chunk(x, Bm, Cm, dt, A_log,
                                          interpret=interpret)

    def combine(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return a1 * a2, s1 * a2[..., None, None] + s2

    Lam_s = jnp.moveaxis(Lam, 1, 0)
    S_s = jnp.moveaxis(S_loc, 1, 0)
    if initial_state is not None:
        Lam_s = jnp.concatenate([jnp.ones_like(Lam_s[:1]), Lam_s], 0)
        S_s = jnp.concatenate([initial_state.astype(jnp.float32)[None], S_s], 0)
    accA, accS = jax.lax.associative_scan(combine, (Lam_s, S_s), axis=0)
    if initial_state is not None:
        S_before = jnp.moveaxis(accS[:-1], 0, 1)
        final = accS[-1]
    else:
        S_before = jnp.moveaxis(
            jnp.concatenate([jnp.zeros_like(accS[:1]), accS[:-1]], 0), 0, 1)
        final = accS[-1]

    dA = dt.astype(jnp.float32) * (-jnp.exp(A_log.astype(jnp.float32)))
    cum = jnp.cumsum(dA, axis=2)
    y_inter = jnp.einsum("bcln,bchnp,bclh->bclhp",
                         Cm.astype(jnp.float32), S_before, jnp.exp(cum))
    return (y_intra + y_inter).astype(x.dtype), final
