"""Decode (single-query) attention over a long KV cache — flash-decoding
style streaming softmax over key blocks, masked by per-sequence lengths.

Grid = (B*H, kv_blocks); one query row per program, KV streamed through
VMEM in (block_k, D) tiles.  Lengths arrive as a scalar-prefetch operand
(SMEM) so masking needs no extra HBM traffic.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.plan import decode_block_plan

NEG_INF = -2.0e38


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, softcap, bk, n_kv_blocks, n_heads):
    bh = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                   # (1, d)
    k = k_ref[0, :, 0].astype(jnp.float32)             # (bk, d)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale    # (1, bk)
    if softcap and softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    length = len_ref[bh // n_heads]
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    s = jnp.where(k_pos < length, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    safe = m_new > NEG_INF / 2
    p = jnp.exp(s - jnp.where(safe, m_new, 0.0)[:, None])
    p = jnp.where(k_pos < length, p, 0.0)
    alpha = jnp.where(m_prev > NEG_INF / 2,
                      jnp.exp(m_prev - jnp.where(safe, m_new, 0.0)), 0.0)

    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == n_kv_blocks - 1)
    def _final():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("softcap", "block_k", "interpret"))
def decode_attention(
    q, k, v, lengths, *,
    softcap: float = 0.0,
    block_k: int = 512,
    interpret: bool = False,
):
    """q: (B,H,D); k/v: (B,T,K,D); lengths: (B,) ints. Returns (B,H,D)."""
    B, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    plan = decode_block_plan(B, H, D, T, K, block_k, q.dtype)
    G, bk, n_kv = plan.meta["G"], plan.meta["bk"], plan.meta["n_kv"]
    scale = 1.0 / math.sqrt(D)

    qf = q.reshape(B * H, 1, D)
    kernel = functools.partial(
        _kernel, scale=scale, softcap=softcap, bk=bk, n_kv_blocks=n_kv,
        n_heads=H)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * H, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda bh, j, *_: (bh, 0, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda bh, j, *_, G=G, H=H: (bh // H, j, (bh % H) // G, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda bh, j, *_, G=G, H=H: (bh // H, j, (bh % H) // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda bh, j, *_: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * H, 1, D), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qf, k, v)
    return out.reshape(B, H, D)
