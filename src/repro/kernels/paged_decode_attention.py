"""Batched paged-KV decode attention — the serving substrate kernel.

The KV cache is a global pool of fixed-size pages ``(n_pages,
page_size, K, D)``; each sequence owns a *block table* row naming the
pages that hold its keys/values in order.  Grid = (B*H, n_max): one
query row per program, one page per grid step.  Both the block tables
and the per-sequence lengths arrive as scalar-prefetch operands (SMEM),
so the page gather happens inside the k/v BlockSpec ``index_map`` —
the DMA engine fetches exactly the pages a sequence owns, and ragged
lengths are masked with zero extra HBM traffic.  The body is the same
flash-decoding streaming softmax as ``decode_attention``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.plan import paged_decode_block_plan

NEG_INF = -2.0e38


def _kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale, softcap, ps, n_max, n_heads):
    bh = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                   # (1, d)
    k = k_ref[0, :, 0].astype(jnp.float32)             # (ps, d)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale    # (1, ps)
    if softcap and softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    # token position of each key in this page; pages past the
    # sequence's length (garbage table entries clamp to page 0) are
    # fully masked, contributing nothing.
    length = len_ref[bh // n_heads]
    k_pos = j * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
    s = jnp.where(k_pos < length, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    safe = m_new > NEG_INF / 2
    p = jnp.exp(s - jnp.where(safe, m_new, 0.0)[:, None])
    p = jnp.where(k_pos < length, p, 0.0)
    alpha = jnp.where(m_prev > NEG_INF / 2,
                      jnp.exp(m_prev - jnp.where(safe, m_new, 0.0)), 0.0)

    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == n_max - 1)
    def _final():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_decode_attention(
    q, k_pages, v_pages, block_tables, lengths, *,
    softcap: float = 0.0,
    interpret: bool = False,
):
    """q: (B,H,D); k_pages/v_pages: (n_pages, page_size, K, D);
    block_tables: (B, n_max) page ids; lengths: (B,) valid key counts.
    Returns (B,H,D).  Table entries past a sequence's page count may be
    arbitrary — they are clamped into range and masked by ``lengths``.
    """
    B, H, D = q.shape
    P, ps, K = k_pages.shape[0], k_pages.shape[1], k_pages.shape[2]
    n_max = block_tables.shape[1]
    plan = paged_decode_block_plan(B, H, D, ps, n_max, P, K, q.dtype)
    G = plan.meta["G"]
    scale = 1.0 / math.sqrt(D)

    qf = q.reshape(B * H, 1, D)
    tables = jnp.clip(block_tables.astype(jnp.int32), 0, P - 1)
    kernel = functools.partial(
        _kernel, scale=scale, softcap=softcap, ps=ps, n_max=n_max,
        n_heads=H)

    def kv_map(bh, j, tbl, lens, G=G, H=H):
        # scalar-prefetch page gather: block index 0 of the page axis is
        # the table entry itself (block size 1 along that axis)
        return (tbl[bh // H, j], 0, (bh % H) // G, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B * H, n_max),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda bh, j, *_: (bh, 0, 0)),
            pl.BlockSpec((1, ps, 1, D), kv_map),
            pl.BlockSpec((1, ps, 1, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda bh, j, *_: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * H, 1, D), q.dtype),
        interpret=interpret,
    )(tables, lengths.astype(jnp.int32), qf, k_pages, v_pages)
    return out.reshape(B, H, D)
