"""Jit'd public wrappers over the Pallas kernels.

``interpret=True`` executes kernel bodies in Python on CPU (the
validation mode on this box); on TPU pass interpret=False (default) for
the compiled Mosaic path.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.plan import ssd_block_plan
from repro.kernels.ssd_scan import ssd_chunked as _ssd_chunked
from repro.kernels.ssd_scan import ssd_intra_chunk as _ssd_intra


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    block_q=256, block_k=256, interpret=False):
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  block_q=block_q, block_k=block_k, interpret=interpret)


def decode_attention(q, k, v, lengths, *, softcap=0.0, block_k=512,
                     interpret=False):
    return _decode(q, k, v, lengths, softcap=softcap, block_k=block_k,
                   interpret=interpret)


def paged_decode_attention(q, k_pages, v_pages, block_tables, lengths, *,
                           softcap=0.0, interpret=False):
    """Batched paged-KV decode: k/v live in a global page pool
    (n_pages, page_size, K, D); block_tables (B, n_max) names each
    sequence's pages; lengths (B,) masks ragged tails."""
    from repro.kernels.paged_decode_attention import (
        paged_decode_attention as _paged,
    )

    return _paged(q, k_pages, v_pages, block_tables, lengths,
                  softcap=softcap, interpret=interpret)


def ssd_chunked(x, Bm, Cm, dt, A_log, *, chunk=128, initial_state=None,
                interpret=False):
    """Unchunked interface: x (B,S,H,P), Bm/Cm (B,S,N), dt (B,S,H)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    plan = ssd_block_plan(B, S, H, P, N, chunk, x.dtype)
    L, nc = plan.meta["L"], plan.meta["nc"]
    y, final = _ssd_chunked(
        x.reshape(B, nc, L, H, P), Bm.reshape(B, nc, L, N),
        Cm.reshape(B, nc, L, N), dt.reshape(B, nc, L, H), A_log,
        initial_state=initial_state, interpret=interpret)
    return y.reshape(B, S, H, P), final


ssd_intra_chunk = _ssd_intra


def slstm_scan(pre, R, *, block_s=128, interpret=False):
    from repro.kernels.slstm_scan import slstm_scan as _s

    return _s(pre, R, block_s=block_s, interpret=interpret)
