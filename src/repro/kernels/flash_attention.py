"""Flash attention for TPU (pl.pallas_call + explicit BlockSpec VMEM tiling).

Streaming-softmax attention over KV blocks with running (m, l, acc)
scratch accumulators.  Supports causal masking, sliding windows, logit
softcapping (gemma2) and GQA (kv-head folding in the index map).

Grid = (batch*q_heads, q_blocks, kv_blocks); the kv dimension is the
minor-most (sequentially iterated on TPU), so VMEM scratch carries the
running softmax state across kv steps.  Block shapes keep the working
set: q (Bq, D) + k/v (Bk, D) + scores (Bq, Bk) in fp32 — with the
default Bq=Bk=256, D<=256 that is < 1.5 MiB, comfortably inside the
~16 MiB VMEM budget with double buffering.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.plan import flash_block_plan

NEG_INF = -2.0e38


def _compiler_params():
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams")
    return cls(dimension_semantics=("parallel", "parallel", "arbitrary"))


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, softcap, bq, bk, n_kv_blocks):
    j = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # (bq, d)
    k = k_ref[0, :, 0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (bq, bk)
    if softcap and softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window and window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    # guard fully-masked rows: exp(NEG_INF - NEG_INF) would be exp(0)=1
    safe = m_new > NEG_INF / 2
    p = jnp.exp(s - jnp.where(safe, m_new, 0.0)[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.where(safe, jnp.exp(m_prev - jnp.where(safe, m_new, 0.0)), 0.0)
    alpha = jnp.where(m_prev > NEG_INF / 2, alpha, 0.0)

    m_ref[...] = m_new
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == n_kv_blocks - 1)
    def _final():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k",
                     "interpret"),
)
def flash_attention(
    q, k, v, *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
):
    """q: (B, S, H, D); k/v: (B, T, K, D) with H % K == 0.  Returns (B,S,H,D).

    Positions are the trivial arange (self-attention over one segment).
    """
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    plan = flash_block_plan(B, S, H, D, T, K, block_q, block_k, q.dtype)
    G, bq, bk = plan.meta["G"], plan.meta["bq"], plan.meta["bk"]
    n_kv = plan.meta["n_kv"]
    scale = 1.0 / math.sqrt(D)

    # layout: (B*H, S, D) for q/o; k/v stay (B, T, K, D), GQA via index map
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        bq=bq, bk=bk, n_kv_blocks=n_kv)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, S // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda bh, i, j, G=G, H=H: (bh // H, j, (bh % H) // G, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda bh, i, j, G=G, H=H: (bh // H, j, (bh % H) // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(qf, k, v)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
