"""Pure-jnp oracles for every kernel (the allclose targets)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0,
                        lengths=None):
    """q: (B,S,H,D); k/v: (B,T,K,D). Plain softmax attention."""
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if softcap and softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kp <= qp
    if window and window > 0:
        mask &= kp > qp - window
    mask = jnp.broadcast_to(mask[None, None], (B, H, S, T))
    if lengths is not None:
        mask &= (kp[None, None] < lengths[:, None, None, None])
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with every key masked produce 0 (matches streaming kernel)
    p = jnp.where(mask.any(-1, keepdims=True), p, 0.0)
    return jnp.einsum("bhst,bthd->bshd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def decode_attention_ref(q, k, v, lengths, *, softcap=0.0):
    """q: (B,H,D) single query at position lengths-1 (inclusive cache);
    k/v: (B,T,K,D); lengths: (B,) valid key count."""
    out = flash_attention_ref(
        q[:, None], k, v, causal=False, softcap=softcap, lengths=lengths)
    return out[:, 0]


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                               *, softcap=0.0):
    """q: (B,H,D); k_pages/v_pages: (n_pages, page_size, K, D);
    block_tables: (B, n_max) page ids; lengths: (B,) valid key counts.

    Gathers each sequence's pages into a contiguous (B, n_max*ps, K, D)
    view and defers to ``decode_attention_ref`` — positions past
    ``lengths`` (including garbage pages) are masked there.
    """
    B = q.shape[0]
    P, ps, K, D = k_pages.shape
    n_max = block_tables.shape[1]
    tables = jnp.clip(block_tables.astype(jnp.int32), 0, P - 1)
    k = k_pages[tables].reshape(B, n_max * ps, K, D)
    v = v_pages[tables].reshape(B, n_max * ps, K, D)
    return decode_attention_ref(q, k, v, lengths, softcap=softcap)


def ssd_chunk_ref(x, Bm, Cm, dt, A_log, *, initial_state=None):
    """Naive per-step SSD recurrence (no D skip, no conv — pure cell).

    x: (B,S,H,P); Bm/Cm: (B,S,N); dt: (B,S,H) post-softplus.
    Returns (y (B,S,H,P), final_state (B,H,N,P)).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    a = -jnp.exp(A_log.astype(jnp.float32))

    def step(s, inp):
        x_t, B_t, C_t, dt_t = inp
        decay = jnp.exp(dt_t * a)
        upd = jnp.einsum("bn,bh,bhp->bhnp", B_t, dt_t, x_t)
        s = s * decay[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", C_t, s)
        return s, y

    s0 = (jnp.zeros((Bsz, H, N, P), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0)
               for t in (x, Bm, Cm, dt))
    final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


def slstm_cell_ref(pre, R):
    """Oracle for kernels.slstm_scan: pre (B,S,4,d), R (4,H,hd,hd)."""
    B, S, _, d = pre.shape
    _, H, hd, _ = R.shape
    Rf = R.astype(jnp.float32)

    def step(carry, p_t):
        c, n, h, m = carry
        hh = h.reshape(B, H, hd)
        rec = [
            jnp.einsum("bhd,hde->bhe", hh, Rf[g]).reshape(B, d)
            for g in range(4)
        ]
        gi = p_t[:, 0] + rec[0]
        gf = p_t[:, 1] + rec[1]
        gz = jnp.tanh(p_t[:, 2] + rec[2])
        go = jax.nn.sigmoid(p_t[:, 3] + rec[3])
        logf = jax.nn.log_sigmoid(gf)
        m_new = jnp.maximum(logf + m, gi)
        fp = jnp.exp(logf + m - m_new)
        ip = jnp.exp(gi - m_new)
        c = fp * c + ip * gz
        n = fp * n + ip
        h = go * c / jnp.maximum(n, 1e-6)
        return (c, n, h, m_new), h

    zeros = jnp.zeros((B, d), jnp.float32)
    carry = (zeros, zeros + 1e-6, zeros, zeros)
    _, hs = jax.lax.scan(step, carry, jnp.moveaxis(pre.astype(jnp.float32), 1, 0))
    return jnp.moveaxis(hs, 0, 1).astype(pre.dtype)
