"""Token samplers."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits, rng=None):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(logits, rng, *, temperature: float = 1.0, top_k: int = 0):
    if temperature <= 0.0:
        return greedy(logits)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[..., -1:]
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(rng, logits).astype(jnp.int32)
