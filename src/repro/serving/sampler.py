"""Token samplers.

The dispatch between greedy and stochastic sampling is *explicit*:
``greedy`` takes no rng (it used to accept-and-ignore one), ``sample``
*requires* one and rejects ``temperature <= 0`` (it used to silently
drop the caller's rng and go greedy).  ``select_token`` is the serving
entry point: temperature is a static Python float, so the dispatch is
resolved at trace time and both branches are deterministic under jit —
the same (rng, temperature) always yields the same token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(logits, rng, *, temperature: float = 1.0, top_k: int = 0):
    if temperature <= 0.0:
        raise ValueError(
            "sample() requires temperature > 0; use greedy() (or "
            "select_token(), which dispatches explicitly) for "
            "deterministic decoding")
    if rng is None:
        raise ValueError("sample() requires an rng key")
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[..., -1:]
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(rng, logits).astype(jnp.int32)


def select_token(logits, rng=None, *, temperature: float = 0.0,
                 top_k: int = 0):
    """Explicit greedy/stochastic dispatch: ``temperature <= 0`` is
    greedy (rng unused, may be None); otherwise ``rng`` is required.
    ``temperature`` must be a static float — the branch is chosen at
    trace time, never a traced conditional."""
    if temperature <= 0.0:
        return greedy(logits)
    return sample(logits, rng, temperature=temperature, top_k=top_k)
