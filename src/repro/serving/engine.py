"""S2M3 multi-task serving engine (real computation).

Brings the paper's architecture to life on actual jax devices:

* one ``ModuleRuntime`` per *distinct* module signature — the
  ``ModuleRegistry`` guarantees a model added later reuses already-
  deployed modules (weights exist once per signature, §IV-B);
* modules live on the device (or device group) chosen by
  ``core.placement``; request inputs are ``jax.device_put`` to the
  hosting device — the ICI/socket transfer of the paper;
* per-request parallel routing: encoder calls are *dispatched* to their
  devices without blocking (XLA async dispatch), so modality encoders
  genuinely overlap; the head runs when all encoder outputs arrive
  (§V, Eq. 2-3).

Used by tests (split == monolithic bit-equivalence) and by
examples/multi_task_serving.py.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax

from repro.core.module import ModelSpec, ModuleSpec
from repro.core.placement import Placement
from repro.core.registry import ModuleRegistry


@dataclasses.dataclass
class ModuleRuntime:
    spec: ModuleSpec
    apply: Callable              # (params, *inputs) -> output (jitted)
    params: Any
    device: Any                  # jax.Device or Sharding


@dataclasses.dataclass
class InferenceResult:
    model: str
    output: Any
    encoder_outputs: dict[str, Any]
    timeline: list[tuple[str, str, float, float]]   # (module, phase, t0, t1)
    latency_s: float


class S2M3Engine:
    def __init__(self, device_map: dict[str, Any] | None = None):
        """device_map: placement device name -> jax.Device.  Defaults to a
        single-device map over jax.devices()[0]."""
        self.registry = ModuleRegistry()
        self.runtimes: dict[str, ModuleRuntime] = {}
        self.device_map = device_map or {"dev0": jax.devices()[0]}
        self.placement: Placement | None = None

    # -- deployment -----------------------------------------------------
    def deploy_model(
        self,
        model: ModelSpec,
        builders: dict[str, Callable[[], tuple[Callable, Any]]],
        placement: Placement | None = None,
    ) -> list[str]:
        """Register a model; build runtimes only for newly needed modules.

        builders: module signature -> () -> (apply_fn, params).
        Returns names of modules actually loaded (sharing = short list).
        """
        new_modules = self.registry.add_model(model)
        if placement is not None:
            self.placement = placement
        loaded = []
        for m in new_modules:
            apply_fn, params = builders[m.name]()
            dev = self._device_for(m.name)
            params = jax.device_put(params, dev)
            self.runtimes[m.name] = ModuleRuntime(
                m, jax.jit(apply_fn), params, dev)
            loaded.append(m.name)
        return loaded

    def evict_model(self, name: str) -> list[str]:
        freed = self.registry.remove_model(name)
        for m in freed:
            self.runtimes.pop(m.name, None)
        return [m.name for m in freed]

    def _device_for(self, module_name: str):
        if self.placement is not None:
            hosts = self.placement.devices_for(module_name)
            if hosts:
                return self.device_map[hosts[0]]
        return next(iter(self.device_map.values()))

    # -- inference ------------------------------------------------------
    def infer(self, model_name: str, inputs: dict[str, Any],
              head_extra: dict | None = None) -> InferenceResult:
        """inputs: modality -> array for each encoder; head receives the
        dict of encoder outputs (by modality) plus head_extra kwargs."""
        model = self.registry.models[model_name]
        t_start = time.perf_counter()
        timeline = []

        # dispatch all encoders without blocking (async device execution);
        # device_put moves the modality payload to the hosting device
        pending: dict[str, Any] = {}
        for enc in model.encoders:
            rt = self.runtimes[enc.name]
            t0 = time.perf_counter()
            x = jax.device_put(inputs[enc.modality], rt.device)
            out = rt.apply(rt.params, x)
            pending[enc.modality] = (enc.name, out, t0)

        enc_outputs = {}
        for modality, (name, out, t0) in pending.items():
            out = jax.block_until_ready(out)
            timeline.append((name, "encode", t0, time.perf_counter()))
            enc_outputs[modality] = out

        head_rt = self.runtimes[model.head.name]
        t0 = time.perf_counter()
        moved = {k: jax.device_put(v, head_rt.device)
                 for k, v in enc_outputs.items()}
        result = head_rt.apply(head_rt.params, moved,
                               **(head_extra or {}))
        result = jax.block_until_ready(result)
        timeline.append((model.head.name, "head", t0, time.perf_counter()))

        return InferenceResult(
            model=model_name, output=result, encoder_outputs=enc_outputs,
            timeline=timeline, latency_s=time.perf_counter() - t_start)

    # -- stats ----------------------------------------------------------
    def deployed_bytes(self) -> int:
        return self.registry.shared_bytes()

    def dedicated_bytes(self) -> int:
        return self.registry.dedicated_bytes()
