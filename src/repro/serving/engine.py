"""S2M3 multi-task serving engine (real computation).

Brings the paper's architecture to life on actual jax devices:

* one ``ModuleRuntime`` per *distinct* module signature — the
  ``ModuleRegistry`` guarantees a model added later reuses already-
  deployed modules (weights exist once per signature, §IV-B);
* modules live on the device (or device group) chosen by
  ``core.placement``; request inputs are ``jax.device_put`` to the
  hosting device — the ICI/socket transfer of the paper;
* per-request parallel routing: encoder calls are *dispatched* to their
  devices without blocking (XLA async dispatch), so modality encoders
  genuinely overlap; the head runs when all encoder outputs arrive
  (§V, Eq. 2-3).

Used by tests (split == monolithic bit-equivalence) and by
examples/multi_task_serving.py.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax

from repro.core.module import ModelSpec, ModuleSpec
from repro.core.placement import Placement
from repro.core.registry import ModuleRegistry


@dataclasses.dataclass
class ModuleRuntime:
    spec: ModuleSpec
    apply: Callable              # (params, *inputs) -> output (jitted)
    params: Any
    device: Any                  # jax.Device or Sharding
    host: str | None = None      # placement device name (routing identity)
    # lazily materialized replica params, host -> device-resident copy.
    # Populated only when routing actually sends traffic to another of
    # the module's placement hosts (see S2M3Engine.params_on).
    replicas: dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class InferenceResult:
    model: str
    output: Any
    encoder_outputs: dict[str, Any]
    timeline: list[tuple[str, str, float, float]]   # (module, phase, t0, t1)
    latency_s: float
    # placement device name each module ran on — comparable with the
    # simulator's per-request routes (s2m3.PlanReport.routes)
    devices: dict[str, str] = dataclasses.field(default_factory=dict)
    rid: int | None = None


class S2M3Engine:
    def __init__(self, device_map: dict[str, Any] | None = None, *,
                 registry: ModuleRegistry | None = None,
                 cluster=None, routing: str = "paper"):
        """device_map: placement device name -> jax.Device.  Defaults to a
        single-device map over jax.devices()[0].  When ``cluster`` is
        given, replica choice among a module's placement hosts goes
        through the named routing policy instead of first-host."""
        self.registry = registry or ModuleRegistry()
        self.runtimes: dict[str, ModuleRuntime] = {}
        self.device_map = device_map or {"dev0": jax.devices()[0]}
        self.placement: Placement | None = None
        self.cluster = cluster
        self.routing = routing
        # optional live queue probe (set by serving.scheduler): () ->
        # core.routing.QueueSnapshot.  When attached, routing decisions
        # consult real per-device occupancy instead of an empty queue.
        self.queue_probe: Callable[[], Any] | None = None

    # -- deployment -----------------------------------------------------
    def deploy_model(
        self,
        model: ModelSpec,
        builders: dict[str, Callable[[], tuple[Callable, Any]]],
        placement: Placement | None = None,
    ) -> list[str]:
        """Register a model; build runtimes only for newly needed modules.

        builders: module signature -> () -> (apply_fn, params).
        Returns names of modules actually loaded (sharing = short list).
        """
        self.registry.add_model(model)
        if placement is not None:
            self.placement = placement
        loaded = []
        for m in model.modules:
            if m.name in self.runtimes:
                continue                      # shared module already live
            apply_fn, params = builders[m.name]()
            host = self._host_for(m.name)
            dev = self._device_for(host)
            params = jax.device_put(params, dev)
            self.runtimes[m.name] = ModuleRuntime(
                m, jax.jit(apply_fn), params, dev, host)
            loaded.append(m.name)
        return loaded

    def evict_model(self, name: str) -> list[str]:
        freed = self.registry.remove_model(name)
        for m in freed:
            self.runtimes.pop(m.name, None)
        return [m.name for m in freed]

    def migrate(self, module_name: str, host: str) -> None:
        """Move a live module's weights to another placement device
        (replan execution: the paper's dynamic-network migration)."""
        rt = self.runtimes.get(module_name)
        if rt is None or host not in self.device_map:
            return
        dev = self.device_map[host]
        cached = rt.replicas.pop(host, None)
        rt.params = cached if cached is not None else \
            jax.device_put(rt.params, dev)
        rt.device, rt.host = dev, host

    def module_hosts(self, module_name: str) -> list[str]:
        """Placement hosts for a module that the engine can actually
        execute on (i.e. present in ``device_map``).  Raises when the
        placement names hosts but none is mapped — previously the engine
        silently ran on an arbitrary device while reporting the unmapped
        host, so real and reported routes diverged."""
        if self.placement is None:
            return []
        hosts = self.placement.devices_for(module_name)
        mapped = [h for h in hosts if h in self.device_map]
        if hosts and not mapped:
            from repro.analysis.diagnostics import PlanError

            raise PlanError(
                f"module {module_name!r} is placed on {list(hosts)} but none "
                f"of those hosts is in device_map {sorted(self.device_map)}; "
                "extend device_map (see Deployment._extend_device_map) or "
                "replan onto mapped devices",
                module=module_name, requested=tuple(hosts),
                available=tuple(sorted(self.device_map)))
        return mapped

    def route_module(self, module_name: str, *, device_free=None,
                     ready_time: float = 0.0, source: str | None = None,
                     request=None) -> str | None:
        """Choose the executing host for one module call.  Replicated
        modules go through the named routing policy; callers holding
        live queue state (the serving scheduler) pass it in, otherwise
        the engine's attached ``queue_probe`` — if any — supplies it, so
        ``queue_aware`` ranks hosts by real occupancy rather than the
        empty deploy-time queue."""
        hosts = self.module_hosts(module_name)
        if not hosts:
            return None
        if len(hosts) > 1 and self.cluster is not None:
            from repro.s2m3.policies import RouteQuery, get_routing

            if device_free is None and self.queue_probe is not None:
                snap = self.queue_probe()
                device_free = snap.free_map()
                ready_time = max(ready_time, snap.t)
            mod = self.registry.modules.get(module_name)
            if mod is not None:
                return get_routing(self.routing)(RouteQuery(
                    module=mod, hosts=tuple(hosts), cluster=self.cluster,
                    source=source, request=request, ready_time=ready_time,
                    device_free=device_free or {}))
        return hosts[0]

    def _host_for(self, module_name: str) -> str | None:
        """Deploy-time host choice (empty-queue tie-break = the
        simulator's choice for a fresh request, unless a live scheduler
        probe is attached)."""
        return self.route_module(module_name)

    def _device_for(self, host: str | None):
        if host is not None and host in self.device_map:
            return self.device_map[host]
        return next(iter(self.device_map.values()))

    def params_on(self, module_name: str, host: str | None):
        """Device-resident params for a module call routed to ``host``.
        The primary copy lives on ``rt.host``; other placement hosts get
        a lazily cached replica (weights still exist once per signature
        per device)."""
        rt = self.runtimes[module_name]
        if host is None or host == rt.host or host not in self.device_map:
            return rt.params
        if host not in rt.replicas:
            rt.replicas[host] = jax.device_put(rt.params,
                                               self.device_map[host])
        return rt.replicas[host]

    # -- batched-apply path (serving.scheduler) -------------------------
    def apply_module(self, module_name: str, x: Any, *,
                     host: str | None = None) -> tuple[Any, str | None]:
        """Run one (possibly batched) module call on ``host`` without
        blocking — XLA dispatch is async; callers block when they
        consume the output.  Returns (output, host_actually_used)."""
        rt = self.runtimes[module_name]
        used = host if host is not None and host in self.device_map else rt.host
        params = self.params_on(module_name, used)
        x = jax.device_put(x, self._device_for(used))
        return rt.apply(params, x), used

    def apply_head(self, module_name: str, enc_outputs: dict[str, Any],
                   head_extra: dict | None = None, *,
                   host: str | None = None) -> tuple[Any, str | None]:
        """Head call: encoder outputs (by modality) move to the head's
        device — the paper's encoder->head transfer."""
        rt = self.runtimes[module_name]
        used = host if host is not None and host in self.device_map else rt.host
        params = self.params_on(module_name, used)
        dev = self._device_for(used)
        moved = {k: jax.device_put(v, dev) for k, v in enc_outputs.items()}
        return rt.apply(params, moved, **(head_extra or {})), used

    # -- inference ------------------------------------------------------
    def infer(self, model_name: str, inputs: dict[str, Any],
              head_extra: dict | None = None,
              rid: int | None = None) -> InferenceResult:
        """inputs: modality -> array for each encoder; head receives the
        dict of encoder outputs (by modality) plus head_extra kwargs."""
        model = self.registry.models[model_name]
        t_start = time.perf_counter()
        timeline = []
        devices = {m.name: rt.host for m in model.modules
                   if (rt := self.runtimes.get(m.name)) and rt.host}

        # dispatch all encoders without blocking (async device execution);
        # device_put moves the modality payload to the hosting device
        pending: dict[str, Any] = {}
        for enc in model.encoders:
            t0 = time.perf_counter()
            out, used = self.apply_module(enc.name, inputs[enc.modality])
            pending[enc.modality] = (enc.name, out, t0)
            if used:
                devices[enc.name] = used

        enc_outputs = {}
        for modality, (name, out, t0) in pending.items():
            out = jax.block_until_ready(out)
            timeline.append((name, "encode", t0, time.perf_counter()))
            enc_outputs[modality] = out

        t0 = time.perf_counter()
        result, used = self.apply_head(model.head.name, enc_outputs,
                                       head_extra)
        result = jax.block_until_ready(result)
        timeline.append((model.head.name, "head", t0, time.perf_counter()))
        if used:
            devices[model.head.name] = used

        return InferenceResult(
            model=model_name, output=result, encoder_outputs=enc_outputs,
            timeline=timeline, latency_s=time.perf_counter() - t_start,
            devices=devices, rid=rid)

    # -- stats ----------------------------------------------------------
    def deployed_bytes(self) -> int:
        return self.registry.shared_bytes()

    def dedicated_bytes(self) -> int:
        return self.registry.dedicated_bytes()
