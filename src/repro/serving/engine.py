"""S2M3 multi-task serving engine (real computation).

Brings the paper's architecture to life on actual jax devices:

* one ``ModuleRuntime`` per *distinct* module signature — the
  ``ModuleRegistry`` guarantees a model added later reuses already-
  deployed modules (weights exist once per signature, §IV-B);
* modules live on the device (or device group) chosen by
  ``core.placement``; request inputs are ``jax.device_put`` to the
  hosting device — the ICI/socket transfer of the paper;
* per-request parallel routing: encoder calls are *dispatched* to their
  devices without blocking (XLA async dispatch), so modality encoders
  genuinely overlap; the head runs when all encoder outputs arrive
  (§V, Eq. 2-3).

Used by tests (split == monolithic bit-equivalence) and by
examples/multi_task_serving.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.core.module import ModelSpec, ModuleSpec
from repro.core.placement import Placement
from repro.core.registry import ModuleRegistry
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Span, Tracer


@dataclasses.dataclass
class ModuleRuntime:
    spec: ModuleSpec
    apply: Callable              # (params, *inputs) -> output (jitted)
    params: Any
    device: Any                  # jax.Device or Sharding
    host: str | None = None      # placement device name (routing identity)
    # lazily materialized replica params, host -> device-resident copy.
    # Populated only when routing actually sends traffic to another of
    # the module's placement hosts (see S2M3Engine.params_on).
    replicas: dict[str, Any] = dataclasses.field(default_factory=dict)


#: modality -> prefill batch key for decoder extras (how encoder outputs
#: reach a generative head's prefill, e.g. a vision encoder's embedding
#: becoming the VLM decoder's image prefix)
EXTRA_KEYS = {"vision": "image_embeds", "audio": "audio_frames"}


@dataclasses.dataclass
class DecoderRuntime:
    """A generative head module: a ModelBundle (prefill / decode_step /
    paged_decode_step) pinned to one host — its paged KV cache lives
    there, so unlike stateless encoders it is not freely re-routable
    mid-stream."""

    spec: ModuleSpec
    bundle: Any
    params: Any
    device: Any
    host: str | None = None
    prefill_jit: Callable = None
    paged_decode_jit: Callable = None
    decode_jit: Callable = None

    @property
    def n_prefix(self) -> int:
        cfg = self.bundle.cfg
        return cfg.n_image_tokens if cfg.has_vision_stub else 0


@dataclasses.dataclass
class InferenceResult:
    model: str
    output: Any
    encoder_outputs: dict[str, Any]
    # obs.trace spans, one per module phase; each still unpacks as the
    # legacy (module, phase, t0, t1) tuple
    timeline: list[Span]
    latency_s: float
    # placement device name each module ran on — comparable with the
    # simulator's per-request routes (s2m3.PlanReport.routes)
    devices: dict[str, str] = dataclasses.field(default_factory=dict)
    rid: int | None = None


class S2M3Engine:
    def __init__(self, device_map: dict[str, Any] | None = None, *,
                 registry: ModuleRegistry | None = None,
                 cluster=None, routing: str = "paper",
                 tracer: Tracer | None = None):
        """device_map: placement device name -> jax.Device.  Defaults to a
        single-device map over jax.devices()[0].  When ``cluster`` is
        given, replica choice among a module's placement hosts goes
        through the named routing policy instead of first-host."""
        self.registry = registry or ModuleRegistry()
        # solo infer()/generate() spans land here; the serving scheduler
        # uses its own epoch-relative tracer for the batched paths
        self.tracer = tracer or Tracer()
        # engine-lifetime instruments (per-module call counts); each
        # ServeScheduler keeps its own per-run registry on top
        self.metrics = MetricsRegistry()
        self.runtimes: dict[str, ModuleRuntime] = {}
        self.decoders: dict[str, DecoderRuntime] = {}
        self.device_map = device_map or {"dev0": jax.devices()[0]}
        self.placement: Placement | None = None
        self.cluster = cluster
        self.routing = routing
        # optional live queue probe (set by serving.scheduler): () ->
        # core.routing.QueueSnapshot.  When attached, routing decisions
        # consult real per-device occupancy instead of an empty queue.
        self.queue_probe: Callable[[], Any] | None = None

    # -- deployment -----------------------------------------------------
    def deploy_model(
        self,
        model: ModelSpec,
        builders: dict[str, Callable[[], tuple[Callable, Any]]],
        placement: Placement | None = None,
    ) -> list[str]:
        """Register a model; build runtimes only for newly needed modules.

        builders: module signature -> () -> (apply_fn, params).
        Returns names of modules actually loaded (sharing = short list).
        """
        self.registry.add_model(model)
        if placement is not None:
            self.placement = placement
        loaded = []
        for m in model.modules:
            if m.name in self.runtimes or m.name in self.decoders:
                continue                      # shared module already live
            apply_or_bundle, params = builders[m.name]()
            host = self._host_for(m.name)
            dev = self._device_for(host)
            params = jax.device_put(params, dev)
            if hasattr(apply_or_bundle, "decode_step"):
                # generative head: the builder returned a ModelBundle
                bundle = apply_or_bundle
                rt = DecoderRuntime(m, bundle, params, dev, host)
                rt.prefill_jit = jax.jit(bundle.prefill)
                # donated cache buffers: every decode step rebinds the
                # cache, so the old buffer is reused in place
                rt.decode_jit = jax.jit(bundle.decode_step,
                                        donate_argnums=(2,))
                if bundle.paged_decode_step is not None:
                    rt.paged_decode_jit = jax.jit(bundle.paged_decode_step,
                                                  donate_argnums=(2,))
                self.decoders[m.name] = rt
            else:
                self.runtimes[m.name] = ModuleRuntime(
                    m, jax.jit(apply_or_bundle), params, dev, host)
            loaded.append(m.name)
        return loaded

    def evict_model(self, name: str) -> list[str]:
        freed = self.registry.remove_model(name)
        for m in freed:
            self.runtimes.pop(m.name, None)
            self.decoders.pop(m.name, None)
        return [m.name for m in freed]

    def migrate(self, module_name: str, host: str) -> None:
        """Move a live module's weights to another placement device
        (replan execution: the paper's dynamic-network migration)."""
        rt = self.runtimes.get(module_name)
        if rt is None or host not in self.device_map:
            return
        dev = self.device_map[host]
        cached = rt.replicas.pop(host, None)
        rt.params = cached if cached is not None else \
            jax.device_put(rt.params, dev)
        rt.device, rt.host = dev, host

    def module_hosts(self, module_name: str) -> list[str]:
        """Placement hosts for a module that the engine can actually
        execute on (i.e. present in ``device_map``).  Raises when the
        placement names hosts but none is mapped — previously the engine
        silently ran on an arbitrary device while reporting the unmapped
        host, so real and reported routes diverged."""
        if self.placement is None:
            return []
        hosts = self.placement.devices_for(module_name)
        mapped = [h for h in hosts if h in self.device_map]
        if hosts and not mapped:
            from repro.analysis.diagnostics import PlanError

            raise PlanError(
                f"module {module_name!r} is placed on {list(hosts)} but none "
                f"of those hosts is in device_map {sorted(self.device_map)}; "
                "extend device_map (see Deployment._extend_device_map) or "
                "replan onto mapped devices",
                module=module_name, requested=tuple(hosts),
                available=tuple(sorted(self.device_map)))
        return mapped

    def route_module(self, module_name: str, *, device_free=None,
                     ready_time: float = 0.0, source: str | None = None,
                     request=None) -> str | None:
        """Choose the executing host for one module call.  Replicated
        modules go through the named routing policy; callers holding
        live queue state (the serving scheduler) pass it in, otherwise
        the engine's attached ``queue_probe`` — if any — supplies it, so
        ``queue_aware`` ranks hosts by real occupancy rather than the
        empty deploy-time queue."""
        hosts = self.module_hosts(module_name)
        if not hosts:
            return None
        if len(hosts) > 1 and self.cluster is not None:
            from repro.s2m3.policies import RouteQuery, get_routing

            if device_free is None and self.queue_probe is not None:
                snap = self.queue_probe()
                device_free = snap.free_map()
                ready_time = max(ready_time, snap.t)
            mod = self.registry.modules.get(module_name)
            if mod is not None:
                return get_routing(self.routing)(RouteQuery(
                    module=mod, hosts=tuple(hosts), cluster=self.cluster,
                    source=source, request=request, ready_time=ready_time,
                    device_free=device_free or {}))
        return hosts[0]

    def _host_for(self, module_name: str) -> str | None:
        """Deploy-time host choice (empty-queue tie-break = the
        simulator's choice for a fresh request, unless a live scheduler
        probe is attached)."""
        return self.route_module(module_name)

    def _device_for(self, host: str | None):
        if host is not None and host in self.device_map:
            return self.device_map[host]
        return next(iter(self.device_map.values()))

    def params_on(self, module_name: str, host: str | None):
        """Device-resident params for a module call routed to ``host``.
        The primary copy lives on ``rt.host``; other placement hosts get
        a lazily cached replica (weights still exist once per signature
        per device)."""
        rt = self.runtimes[module_name]
        if host is None or host == rt.host or host not in self.device_map:
            return rt.params
        if host not in rt.replicas:
            rt.replicas[host] = jax.device_put(rt.params,
                                               self.device_map[host])
        return rt.replicas[host]

    # -- batched-apply path (serving.scheduler) -------------------------
    def apply_module(self, module_name: str, x: Any, *,
                     host: str | None = None) -> tuple[Any, str | None]:
        """Run one (possibly batched) module call on ``host`` without
        blocking — XLA dispatch is async; callers block when they
        consume the output.  Returns (output, host_actually_used)."""
        rt = self.runtimes[module_name]
        used = host if host is not None and host in self.device_map else rt.host
        params = self.params_on(module_name, used)
        x = jax.device_put(x, self._device_for(used))
        self.metrics.counter("engine.module_calls", module=module_name).inc()
        return rt.apply(params, x), used

    def apply_head(self, module_name: str, enc_outputs: dict[str, Any],
                   head_extra: dict | None = None, *,
                   host: str | None = None) -> tuple[Any, str | None]:
        """Head call: encoder outputs (by modality) move to the head's
        device — the paper's encoder->head transfer."""
        rt = self.runtimes[module_name]
        used = host if host is not None and host in self.device_map else rt.host
        params = self.params_on(module_name, used)
        dev = self._device_for(used)
        moved = {k: jax.device_put(v, dev) for k, v in enc_outputs.items()}
        self.metrics.counter("engine.head_calls", module=module_name).inc()
        return rt.apply(params, moved, **(head_extra or {})), used

    # -- generative (decoder-head) path ---------------------------------
    def decoder_runtime(self, module_name: str) -> DecoderRuntime:
        rt = self.decoders.get(module_name)
        if rt is None:
            raise KeyError(
                f"module {module_name!r} has no decoder runtime; "
                "generative heads need a builder returning "
                "(ModelBundle, params)")
        return rt

    @staticmethod
    def gen_batch(prompt, enc_outputs: dict[str, Any]) -> dict[str, Any]:
        """Batch-1 prefill inputs for a generative head: prompt tokens
        plus encoder outputs mapped through ``EXTRA_KEYS`` (e.g. a
        vision encoder's embedding feeding the VLM image prefix)."""
        import jax.numpy as jnp

        batch = {"tokens": jnp.asarray([list(prompt)], jnp.int32)}
        for modality, key in EXTRA_KEYS.items():
            if modality in enc_outputs:
                v = jnp.asarray(enc_outputs[modality])
                batch[key] = v if v.ndim == 3 else v[None]
        return batch

    def init_paged_cache(self, module_name: str, n_pages: int,
                         page_size: int, dtype=None):
        import jax.numpy as jnp

        rt = self.decoder_runtime(module_name)
        cache = rt.bundle.init_paged_cache(n_pages, page_size,
                                           dtype or jnp.float32)
        return jax.device_put(cache, rt.device)

    def apply_prefill(self, module_name: str, batch: dict[str, Any],
                      cache) -> tuple[Any, Any]:
        """Batch-1 prefill on the decoder's pinned host; returns
        (last-token logits, filled dense cache)."""
        rt = self.decoder_runtime(module_name)
        batch = {k: jax.device_put(v, rt.device) for k, v in batch.items()}
        self.metrics.counter("engine.prefills", module=module_name).inc()
        return rt.prefill_jit(rt.params, batch, cache)

    def apply_paged_decode(self, module_name: str, tokens, cache,
                           block_tables, lengths) -> tuple[Any, Any]:
        """One batched decode step over the paged KV cache.  The cache
        argument is donated — callers must rebind to the returned cache
        and never reuse the old reference."""
        rt = self.decoder_runtime(module_name)
        if rt.paged_decode_jit is None:
            raise NotImplementedError(
                f"decoder {module_name!r} (family "
                f"{rt.bundle.cfg.family!r}) has no paged decode path")
        self.metrics.counter("engine.decode_steps", module=module_name).inc()
        return rt.paged_decode_jit(rt.params, tokens, cache,
                                   block_tables, lengths)

    def generate(self, request) -> InferenceResult:
        """Solo generative inference: encoders run as in ``infer()``;
        the head prefills a batch-1 dense cache and decodes
        sequentially.  This is the single-sequence oracle the batched
        paged decode streams are compared against."""
        import jax.numpy as jnp
        import numpy as np

        from repro.serving.sampler import select_token

        model = self.registry.models[request.model]
        if request.prompt is None:
            raise ValueError(
                f"request {request.rid} targets generative model "
                f"{request.model!r} but has no prompt")
        rt = self.decoder_runtime(model.head.name)
        now = self.tracer.clock
        t_start = now()
        root = self.tracer.begin("request", "request", rid=request.rid,
                                 t0=t_start, model=request.model)
        timeline = []
        devices = {}
        # head-only models may carry precomputed modality features as
        # inputs (e.g. image embeds for a VLM without a deployed vision
        # encoder); live encoders overwrite their modality below
        enc_outputs: dict[str, Any] = dict(request.inputs or {})
        for enc in model.encoders:
            t0 = now()
            out, used = self.apply_module(enc.name, request.inputs[enc.modality])
            out = jax.block_until_ready(out)
            timeline.append(self.tracer.record(
                enc.name, "encode", t0, now(), rid=request.rid,
                parent=root, host=used))
            enc_outputs[enc.modality] = out
            if used:
                devices[enc.name] = used
        if rt.host:
            devices[model.head.name] = rt.host

        prompt = list(request.prompt)
        max_new = max(int(request.max_new_tokens), 1)
        total = rt.n_prefix + len(prompt) + max_new + 1
        T = -(-total // 8) * 8
        cache = rt.bundle.init_cache(1, T, jnp.float32)
        t0 = now()
        logits, cache = self.apply_prefill(
            model.head.name, self.gen_batch(prompt, enc_outputs), cache)
        timeline.append(self.tracer.record(
            model.head.name, "prefill", t0, now(), rid=request.rid,
            parent=root, prompt_tokens=len(prompt)))

        rng = jax.random.PRNGKey((request.rid or 0) & 0x7FFFFFFF)
        rng, k = jax.random.split(rng)
        toks = [int(select_token(logits[0], k,
                                 temperature=request.temperature))]
        L = rt.n_prefix + len(prompt)
        t0 = now()
        while (len(toks) < max_new and toks[-1] != request.eos_id
               and L < T - 1):
            logits, cache = rt.decode_jit(
                rt.params, jnp.asarray([[toks[-1]]], jnp.int32), cache,
                jnp.asarray([L], jnp.int32))
            L += 1
            rng, k = jax.random.split(rng)
            toks.append(int(select_token(logits[0], k,
                                         temperature=request.temperature)))
        timeline.append(self.tracer.record(
            model.head.name, "decode", t0, now(), rid=request.rid,
            parent=root, new_tokens=len(toks)))
        t_end = now()
        self.tracer.end(root, t1=t_end)
        return InferenceResult(
            model=request.model, output=np.asarray(toks, np.int32),
            encoder_outputs=enc_outputs, timeline=timeline,
            latency_s=t_end - t_start, devices=devices,
            rid=request.rid)

    # -- inference ------------------------------------------------------
    def infer(self, model_name: str, inputs: dict[str, Any],
              head_extra: dict | None = None,
              rid: int | None = None) -> InferenceResult:
        """inputs: modality -> array for each encoder; head receives the
        dict of encoder outputs (by modality) plus head_extra kwargs."""
        model = self.registry.models[model_name]
        if model.head.name in self.decoders:
            raise ValueError(
                f"model {model_name!r} has a generative head; use "
                "generate(request) for solo inference or the serving "
                "scheduler for batched decode")
        now = self.tracer.clock
        t_start = now()
        root = self.tracer.begin("request", "request", rid=rid,
                                 t0=t_start, model=model_name)
        timeline = []
        devices = {m.name: rt.host for m in model.modules
                   if (rt := self.runtimes.get(m.name)) and rt.host}

        # dispatch all encoders without blocking (async device execution);
        # device_put moves the modality payload to the hosting device
        pending: dict[str, Any] = {}
        for enc in model.encoders:
            t0 = now()
            out, used = self.apply_module(enc.name, inputs[enc.modality])
            pending[enc.modality] = (enc.name, out, t0)
            if used:
                devices[enc.name] = used

        enc_outputs = {}
        for modality, (name, out, t0) in pending.items():
            out = jax.block_until_ready(out)
            timeline.append(self.tracer.record(
                name, "encode", t0, now(), rid=rid, parent=root,
                host=devices.get(name)))
            enc_outputs[modality] = out

        t0 = now()
        result, used = self.apply_head(model.head.name, enc_outputs,
                                       head_extra)
        result = jax.block_until_ready(result)
        timeline.append(self.tracer.record(
            model.head.name, "head", t0, now(), rid=rid, parent=root,
            host=used))
        if used:
            devices[model.head.name] = used

        t_end = now()
        self.tracer.end(root, t1=t_end)
        return InferenceResult(
            model=model_name, output=result, encoder_outputs=enc_outputs,
            timeline=timeline, latency_s=t_end - t_start,
            devices=devices, rid=rid)

    # -- stats ----------------------------------------------------------
    def deployed_bytes(self) -> int:
        return self.registry.shared_bytes()

    def dedicated_bytes(self) -> int:
        return self.registry.dedicated_bytes()
