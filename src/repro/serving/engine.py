"""S2M3 multi-task serving engine (real computation).

Brings the paper's architecture to life on actual jax devices:

* one ``ModuleRuntime`` per *distinct* module signature — the
  ``ModuleRegistry`` guarantees a model added later reuses already-
  deployed modules (weights exist once per signature, §IV-B);
* modules live on the device (or device group) chosen by
  ``core.placement``; request inputs are ``jax.device_put`` to the
  hosting device — the ICI/socket transfer of the paper;
* per-request parallel routing: encoder calls are *dispatched* to their
  devices without blocking (XLA async dispatch), so modality encoders
  genuinely overlap; the head runs when all encoder outputs arrive
  (§V, Eq. 2-3).

Used by tests (split == monolithic bit-equivalence) and by
examples/multi_task_serving.py.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax

from repro.core.module import ModelSpec, ModuleSpec
from repro.core.placement import Placement
from repro.core.registry import ModuleRegistry


@dataclasses.dataclass
class ModuleRuntime:
    spec: ModuleSpec
    apply: Callable              # (params, *inputs) -> output (jitted)
    params: Any
    device: Any                  # jax.Device or Sharding
    host: str | None = None      # placement device name (routing identity)


@dataclasses.dataclass
class InferenceResult:
    model: str
    output: Any
    encoder_outputs: dict[str, Any]
    timeline: list[tuple[str, str, float, float]]   # (module, phase, t0, t1)
    latency_s: float
    # placement device name each module ran on — comparable with the
    # simulator's per-request routes (s2m3.PlanReport.routes)
    devices: dict[str, str] = dataclasses.field(default_factory=dict)
    rid: int | None = None


class S2M3Engine:
    def __init__(self, device_map: dict[str, Any] | None = None, *,
                 registry: ModuleRegistry | None = None,
                 cluster=None, routing: str = "paper"):
        """device_map: placement device name -> jax.Device.  Defaults to a
        single-device map over jax.devices()[0].  When ``cluster`` is
        given, replica choice among a module's placement hosts goes
        through the named routing policy instead of first-host."""
        self.registry = registry or ModuleRegistry()
        self.runtimes: dict[str, ModuleRuntime] = {}
        self.device_map = device_map or {"dev0": jax.devices()[0]}
        self.placement: Placement | None = None
        self.cluster = cluster
        self.routing = routing

    # -- deployment -----------------------------------------------------
    def deploy_model(
        self,
        model: ModelSpec,
        builders: dict[str, Callable[[], tuple[Callable, Any]]],
        placement: Placement | None = None,
    ) -> list[str]:
        """Register a model; build runtimes only for newly needed modules.

        builders: module signature -> () -> (apply_fn, params).
        Returns names of modules actually loaded (sharing = short list).
        """
        self.registry.add_model(model)
        if placement is not None:
            self.placement = placement
        loaded = []
        for m in model.modules:
            if m.name in self.runtimes:
                continue                      # shared module already live
            apply_fn, params = builders[m.name]()
            host = self._host_for(m.name)
            dev = self._device_for(host)
            params = jax.device_put(params, dev)
            self.runtimes[m.name] = ModuleRuntime(
                m, jax.jit(apply_fn), params, dev, host)
            loaded.append(m.name)
        return loaded

    def evict_model(self, name: str) -> list[str]:
        freed = self.registry.remove_model(name)
        for m in freed:
            self.runtimes.pop(m.name, None)
        return [m.name for m in freed]

    def migrate(self, module_name: str, host: str) -> None:
        """Move a live module's weights to another placement device
        (replan execution: the paper's dynamic-network migration)."""
        rt = self.runtimes.get(module_name)
        if rt is None or host not in self.device_map:
            return
        dev = self.device_map[host]
        rt.params = jax.device_put(rt.params, dev)
        rt.device, rt.host = dev, host

    def _host_for(self, module_name: str) -> str | None:
        """Placement device name for a module; replicated modules go
        through the routing policy (empty-queue tie-break = the
        simulator's choice for a fresh request)."""
        if self.placement is None:
            return None
        hosts = self.placement.devices_for(module_name)
        hosts = [h for h in hosts if h in self.device_map] or hosts
        if not hosts:
            return None
        if len(hosts) > 1 and self.cluster is not None:
            from repro.s2m3.policies import RouteQuery, get_routing

            mod = self.registry.modules.get(module_name)
            if mod is not None:
                return get_routing(self.routing)(RouteQuery(
                    module=mod, hosts=tuple(hosts), cluster=self.cluster))
        return hosts[0]

    def _device_for(self, host: str | None):
        if host is not None and host in self.device_map:
            return self.device_map[host]
        return next(iter(self.device_map.values()))

    # -- inference ------------------------------------------------------
    def infer(self, model_name: str, inputs: dict[str, Any],
              head_extra: dict | None = None,
              rid: int | None = None) -> InferenceResult:
        """inputs: modality -> array for each encoder; head receives the
        dict of encoder outputs (by modality) plus head_extra kwargs."""
        model = self.registry.models[model_name]
        t_start = time.perf_counter()
        timeline = []
        devices = {m.name: rt.host for m in model.modules
                   if (rt := self.runtimes.get(m.name)) and rt.host}

        # dispatch all encoders without blocking (async device execution);
        # device_put moves the modality payload to the hosting device
        pending: dict[str, Any] = {}
        for enc in model.encoders:
            rt = self.runtimes[enc.name]
            t0 = time.perf_counter()
            x = jax.device_put(inputs[enc.modality], rt.device)
            out = rt.apply(rt.params, x)
            pending[enc.modality] = (enc.name, out, t0)

        enc_outputs = {}
        for modality, (name, out, t0) in pending.items():
            out = jax.block_until_ready(out)
            timeline.append((name, "encode", t0, time.perf_counter()))
            enc_outputs[modality] = out

        head_rt = self.runtimes[model.head.name]
        t0 = time.perf_counter()
        moved = {k: jax.device_put(v, head_rt.device)
                 for k, v in enc_outputs.items()}
        result = head_rt.apply(head_rt.params, moved,
                               **(head_extra or {}))
        result = jax.block_until_ready(result)
        timeline.append((model.head.name, "head", t0, time.perf_counter()))

        return InferenceResult(
            model=model_name, output=result, encoder_outputs=enc_outputs,
            timeline=timeline, latency_s=time.perf_counter() - t_start,
            devices=devices, rid=rid)

    # -- stats ----------------------------------------------------------
    def deployed_bytes(self) -> int:
        return self.registry.shared_bytes()

    def dedicated_bytes(self) -> int:
        return self.registry.dedicated_bytes()
