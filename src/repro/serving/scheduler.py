"""Continuous-batching serving scheduler: shared *compute*, not just
shared weights.

The paper's §IV-B sharing argument is about deployment cost — one CLIP
text encoder serves VQA, retrieval, and captioning.  This scheduler
extends the argument to execution: requests from *different tasks* that
route through the same module are coalesced into one batched device
call, so a single text-encoder launch serves a VQA request, a retrieval
request, and a captioning request simultaneously.

Architecture
============

* **Per-module request queues.**  ``submit()`` decomposes a
  ``Request`` into one stage per encoder module (head-only models get a
  head stage directly).  Each stage lands in its module's FIFO queue.
* **Admission control / backpressure.**  A queue deeper than
  ``max_queue_depth`` refuses new work: ``admission="block"`` drains
  scheduler steps until the queue recedes (the submitting producer is
  slowed down); ``admission="reject"`` raises ``QueueFull`` so an
  upstream load-balancer can shed.
* **Batch formation.**  Each ``step()`` services the deepest queue —
  the one with the most coalescing opportunity — popping up to
  ``max_batch`` stages whose payloads are stack-compatible (same dtype
  and trailing dims; the leading axis is the batch axis).  The stacked
  call runs once on the routed host and the output is split back
  per-request, so every request's result is the same as its solo
  ``submit()`` (per-example math is independent; only XLA fusion order
  differs, hence allclose rather than bit-equal across batch sizes).
* **Real queue-aware routing.**  The scheduler keeps a per-host
  ``device_free`` occupancy map in *predicted* seconds: after
  dispatching a k-batch of module m to host h it advances h's
  busy-until by ``t_comp(m, h) * batch_factor(k)``.  That map — a
  ``core.routing.QueueSnapshot`` — feeds ``RouteQuery.device_free``,
  so the ``queue_aware`` policy ranks replica hosts by live load
  instead of the engine's always-empty deploy-time queue, and the
  engine's own ``queue_probe`` hook lets deploy/replan-time routing see
  the same state.
* **Heads run per-request** (their inputs are modality-keyed dicts plus
  request-specific ``head_extra`` kwargs — stacking them would change
  semantics), but they still flow through module queues so the stats
  cover the whole pipeline.
* **Generative heads stream through the paged-KV decode substrate.**
  Models whose head is ``ModuleSpec.generative`` don't get a head
  stage: once their encoder stages finish, the request enters the
  head's ``DecodeStream`` (serving.decode) — admission against the page
  pool, batch-1 prefill, then continuous batched decoding where every
  live sequence (across tasks) shares one ``paged_decode_attention``
  launch per step.  The stream's depth participates in the same
  backpressure and deepest-queue servicing as encoder queues, and its
  launches charge the decoder host's occupancy map so ``queue_aware``
  routing sees decode traffic too.

Batching model vs. the paper's footnote-4 fit
=============================================

The paper models a batched module call as
``t(k) = t(1) * (0.684 + 0.316 k)`` — the linear fit of its footnote-4
measurements (1.28 s / 4.90 s / 9.16 s at batch 1/10/20): a fixed
launch cost amortized over k requests, with per-request marginal cost
~0.316 t(1).  This scheduler *realizes* that regime — one launch per
formed batch — and reuses the same ``batch_factor(k)`` fit for its
occupancy predictions, so the simulator's batched-latency predictions
and the scheduler's routing estimates speak one language and the
emitted queue/batch-occupancy stats are directly checkable against
``simulate(coalesce_window=...)`` runs.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.routing import QueueSnapshot, Request, batch_factor
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer
from repro.serving.decode import DecodeStream
from repro.serving.engine import InferenceResult, S2M3Engine


class QueueFull(RuntimeError):
    """Admission refused: a module queue is at ``max_queue_depth`` and
    the scheduler was configured with ``admission="reject"``."""


@dataclass(frozen=True)
class SchedulerConfig:
    max_batch: int = 8            # stages per formed module batch
    max_queue_depth: int = 32     # per-module admission limit
    admission: str = "block"      # "block" (drain) | "reject" (QueueFull)
    # paged-KV decode substrate (per generative head module)
    decode_rows: int = 4          # concurrent sequences per decode batch
    decode_pages: int = 64        # KV page pool size (incl. 1 dummy page)
    page_size: int = 16           # tokens per KV page
    max_seq_len: int = 256        # prefix + prompt + max_new_tokens cap
    # evaluate the runtime subset of repro.analysis.invariants after
    # every scheduler step while draining (PlanError on violation);
    # cheap at serving scale, disable for microbenchmarks
    debug_invariants: bool = True

    def __post_init__(self):
        if self.max_batch < 1 or self.max_queue_depth < 1:
            raise ValueError("max_batch and max_queue_depth must be >= 1")
        if self.admission not in ("block", "reject"):
            raise ValueError(f"unknown admission mode {self.admission!r}")
        if self.decode_rows < 1 or self.page_size < 1 or self.max_seq_len < 1:
            raise ValueError(
                "decode_rows, page_size and max_seq_len must be >= 1")
        n_max = -(-self.max_seq_len // self.page_size)
        if self.decode_pages < n_max + 1:
            raise ValueError(
                f"decode_pages={self.decode_pages} cannot hold one "
                f"max_seq_len={self.max_seq_len} sequence ({n_max} pages) "
                "plus the dummy page")


#: legacy per-module stats_dict() keys, now a compatibility view over
#: the serve.* instruments in ``ServeScheduler.metrics``
STAT_KEYS = ("module", "calls", "stages", "mean_occupancy", "max_batch",
             "cross_task_batches", "max_depth")


@dataclass
class _Stage:
    rid: int
    module: str
    request: Request
    x: Any = None                         # encoder payload (None for heads)
    wait_sid: int = -1                    # queue-wait span (admission)


@dataclass
class _InFlight:
    request: Request
    t_admit: float
    pending: set[str]                     # encoder module names outstanding
    root_sid: int = -1                    # the request's root trace span
    enc_outputs: dict[str, Any] = field(default_factory=dict)
    devices: dict[str, str] = field(default_factory=dict)
    timeline: list = field(default_factory=list)


class ServeScheduler:
    """Continuous-batching core over a live ``S2M3Engine``."""

    def __init__(self, engine: S2M3Engine, *,
                 config: SchedulerConfig | None = None, on_finish=None,
                 tracer: Tracer | None = None):
        self.engine = engine
        self.cfg = config or SchedulerConfig()
        # streaming hook: called with each InferenceResult as its
        # sequence finishes (generative requests finish out of admission
        # order — shorter decodes stream back first)
        self.on_finish = on_finish
        self.queues: dict[str, deque[_Stage]] = {}
        self.decode: dict[str, DecodeStream] = {}
        self.inflight: dict[int, _InFlight] = {}
        self.results: dict[int, InferenceResult] = {}
        self._free_at: dict[str, float] = {}   # host -> predicted busy-until
        self._epoch = time.perf_counter()
        # fresh per-scheduler registry: stats_dict() stays zeroed until
        # this scheduler actually serves (dep.serve() builds one per call)
        self.metrics = MetricsRegistry()
        self.tracer = tracer or Tracer(clock=self._now)
        # guards queues/inflight/results/_free_at; RLock so a
        # blocked submit() may re-enter through step().  Discipline
        # (enforced by repro.analysis.concurrency_lint): mutate shared
        # state only under the lock; never dispatch device work while
        # holding it.
        self._lock = threading.RLock()
        # the engine's routing now sees real queues, not empty ones
        engine.queue_probe = self.snapshot

    # -- introspection --------------------------------------------------
    def _now(self) -> float:
        return time.perf_counter() - self._epoch

    def snapshot(self) -> QueueSnapshot:
        with self._lock:
            return QueueSnapshot(
                t=self._now(),
                device_free=tuple(sorted(self._free_at.items())),
                depths=tuple(sorted((m, len(q))
                                    for m, q in self.queues.items())))

    def queue_depths(self) -> dict[str, int]:
        with self._lock:
            depths = {m: len(q) for m, q in self.queues.items() if q}
            streams = dict(self.decode)
        for m, stream in streams.items():
            d = stream.depth()
            if d:
                depths[m] = depths.get(m, 0) + d
        return depths

    def _module_row(self, module: str) -> dict[str, Any]:
        mt = self.metrics
        occ = mt.get("serve.batch_occupancy", module=module)
        return {
            "module": module,
            "calls": int(mt.value("serve.calls", module=module)),
            "stages": int(mt.value("serve.stages", module=module)),
            "mean_occupancy": round(occ.mean, 3) if occ is not None else 0.0,
            "max_batch": int(occ.max) if occ is not None else 0,
            "cross_task_batches": int(
                mt.value("serve.cross_task_batches", module=module)),
            "max_depth": int(mt.value("serve.max_depth", module=module)),
        }

    def stats_dict(self) -> dict[str, dict[str, Any]]:
        """Stable-schema stats: one row per deployed module (plus any
        queue that ever formed), all counter keys present and zeroed
        even before the first ``serve()``/``step()``.  A compatibility
        view over the ``serve.*`` instruments in ``self.metrics``.
        Generative head rows additionally carry the decode-substrate
        counters and page-occupancy keys from their ``DecodeStream``."""
        names = set(self.engine.registry.modules)
        names.update(self.metrics.label_values("serve.max_depth", "module"))
        names.update(self.metrics.label_values("serve.calls", "module"))
        with self._lock:
            streams = dict(self.decode)
        rows = {m: self._module_row(m) for m in sorted(names)}
        for m, stream in streams.items():
            rows.setdefault(m, self._module_row(m))
            rows[m].update(stream.stats_dict())
        return rows

    @property
    def cross_task_batches(self) -> int:
        return int(self.metrics.total("serve.cross_task_batches"))

    @property
    def cross_task_decode_batches(self) -> int:
        """Batched decode steps whose live rows spanned >= 2 models —
        the generative analogue of ``cross_task_batches``."""
        with self._lock:
            streams = dict(self.decode)
        return sum(s.cross_task_decode_batches for s in streams.values())

    # -- runtime invariants ---------------------------------------------
    def inflight_models(self) -> set[str]:
        """Model names with requests currently in flight (queued,
        encoding, or decoding) — what ``Deployment.evict()`` consults
        before deregistering a model out from under its requests."""
        with self._lock:
            return {fl.request.model for fl in self.inflight.values()}

    def check_invariants(self, *, raise_on_violation: bool = True):
        """Evaluate the runtime subset of the shared invariant catalog
        (``repro.analysis.invariants``) against live serving state:
        every decode stream's page/row/reservation accounting plus
        registry refcount consistency against the in-flight set.  The
        same predicates the model checker exhausts over the schedule
        space — one catalog, three enforcement layers."""
        from repro.analysis.diagnostics import Diagnostic, PlanError, Severity
        from repro.analysis.invariants import StateView, check_state

        violations: list[tuple[str, str]] = []
        with self._lock:
            streams = dict(self.decode)
        for module, stream in streams.items():
            for name, msg in check_state(stream.state_view(),
                                         where="runtime"):
                violations.append((name, f"decode[{module}]: {msg}"))
        registry = self.engine.registry
        models = registry.models
        module_models = {
            mod: tuple(sorted(mdl.name for mdl in models.values()
                              if mod in {m.name for m in mdl.modules}))
            for mod in registry.modules}
        view = StateView(
            refcounts={mod: registry.refcount(mod)
                       for mod in registry.modules},
            module_models=module_models,
            inflight_models=tuple(sorted(self.inflight_models())),
            registered_models=tuple(sorted(models)))
        violations += [(n, f"registry: {m}")
                       for n, m in check_state(view, where="runtime")]
        if violations and raise_on_violation:
            diags = [Diagnostic(Severity.ERROR, f"invariant/{name}", msg,
                                entity="ServeScheduler")
                     for name, msg in violations]
            raise PlanError(
                "runtime invariant violation while serving:\n"
                + "\n".join(d.format() for d in diags), diagnostics=diags)
        return violations

    # -- admission ------------------------------------------------------
    def submit(self, request: Request) -> None:
        """Admit one request: split into per-module stages and enqueue,
        applying backpressure when a target queue is at depth.
        Generative models skip the head queue — after their encoders
        finish they enter the head's paged decode stream instead."""
        model = self.engine.registry.models[request.model]
        if model.encoders and request.inputs is None:
            raise ValueError(
                f"request {request.rid} has no inputs payload; serving "
                "needs Request(inputs={modality: array})")
        stream = None
        if model.head.generative:
            stream = self._ensure_stream(model.head.name)
            stream.validate(request)      # fail fast, before encoder admit
        root = self.tracer.begin("request", "request", rid=request.rid,
                                 model=request.model)
        targets = [m.name for m in model.encoders] + [model.head.name]
        try:
            for t in targets:
                while self._at_depth(t):
                    if self.cfg.admission == "reject":
                        raise QueueFull(
                            f"module queue {t!r} at max_queue_depth="
                            f"{self.cfg.max_queue_depth}")
                    if not self.step():
                        break             # nothing serviceable: admit anyway
        except QueueFull:
            self.tracer.end(root, rejected=True)
            raise
        fl = _InFlight(request, self._now(),
                       pending={m.name for m in model.encoders},
                       root_sid=root)
        with self._lock:
            self.inflight[request.rid] = fl
        if model.encoders:
            for enc in model.encoders:
                self._enqueue(_Stage(request.rid, enc.name, request,
                                     x=request.inputs[enc.modality]))
        elif stream is not None:
            # head-only generative: any inputs payload carries
            # precomputed modality features (e.g. VLM image embeds)
            stream.submit(request.rid, request, dict(request.inputs or {}),
                          parent=root)
        else:
            self._enqueue(_Stage(request.rid, model.head.name, request))

    def _ensure_stream(self, module: str) -> DecodeStream:
        with self._lock:
            stream = self.decode.get(module)
        if stream is None:
            # paged-cache allocation is device work: build outside the lock
            stream = DecodeStream(
                self.engine, module, rows=self.cfg.decode_rows,
                n_pages=self.cfg.decode_pages, page_size=self.cfg.page_size,
                max_seq_len=self.cfg.max_seq_len, now=self._now,
                tracer=self.tracer, metrics=self.metrics)
            with self._lock:
                stream = self.decode.setdefault(module, stream)
        return stream

    def _at_depth(self, module: str) -> bool:
        with self._lock:
            depth = len(self.queues.get(module, ()))
            stream = self.decode.get(module)
        if stream is not None:
            depth += stream.depth()
        return depth >= self.cfg.max_queue_depth

    def _enqueue(self, stage: _Stage) -> None:
        with self._lock:
            q = self.queues.setdefault(stage.module, deque())
            q.append(stage)
            depth = len(q)
            root = self.inflight[stage.rid].root_sid
        stage.wait_sid = self.tracer.begin(stage.module, "admission",
                                           rid=stage.rid, parent=root)
        self.metrics.gauge("serve.max_depth",
                           module=stage.module).track_max(depth)

    # -- scheduling -----------------------------------------------------
    def step(self) -> bool:
        """Service the deepest non-empty queue (most coalescing
        opportunity); decode streams compete on waiting + live depth.
        Returns False when there is nothing to do."""
        with self._lock:
            depths = {m: len(q) for m, q in self.queues.items() if q}
            streams = dict(self.decode)
        for m, stream in streams.items():
            d = stream.depth()
            if d:
                depths[m] = depths.get(m, 0) + d
        module = max(depths, key=lambda m: depths[m], default=None)
        if module is None:
            return False
        self._service(module)
        return True

    def drain(self) -> dict[int, InferenceResult]:
        """Run until no queue has work; returns a consistent snapshot of
        the results (the live dict keeps changing under concurrent
        submitters).  With ``cfg.debug_invariants`` every step is
        followed by a runtime evaluation of the shared invariant
        catalog (page conservation, reservation soundness, refcounts) —
        the same predicates the model checker exhausts offline."""
        while self.step():
            if self.cfg.debug_invariants:
                self.check_invariants()
        if self.cfg.debug_invariants:
            self.check_invariants()
        with self._lock:
            return dict(self.results)

    def serve(self, workload: list[Request]) -> list[InferenceResult]:
        """Drain a whole workload: admit in arrival order (backpressure
        included), run to completion, return results in workload order."""
        for q in sorted(workload, key=lambda r: (r.arrival, r.rid)):
            self.submit(q)
        results = self.drain()
        return [results[q.rid] for q in workload]

    # -- execution ------------------------------------------------------
    def _service(self, module: str) -> None:
        with self._lock:
            stream = self.decode.get(module)
        if stream is not None:
            self._service_decode(module, stream)
            return
        spec = self.engine.registry.modules.get(module)
        is_encoder = spec is not None and spec.kind == "encoder"
        # form the batch under the lock; dispatch outside it
        with self._lock:
            q = self.queues.get(module)
            if not q:
                return
            head = q.popleft()
            batch = [head]
            if is_encoder:
                skipped = []
                sig = self._shape_sig(head.x)
                while q and len(batch) < self.cfg.max_batch:
                    s = q.popleft()
                    if sig is not None and self._shape_sig(s.x) == sig:
                        batch.append(s)
                    else:
                        skipped.append(s)  # incompatible payload: stays FIFO
                q.extendleft(reversed(skipped))
        t_pop = self._now()
        for s in batch:
            if s.wait_sid >= 0:
                self.tracer.end(s.wait_sid, t1=t_pop)
        if is_encoder:
            self._run_encoder_batch(module, batch, t_pop)
        else:
            self._run_head(module, batch[0], t_pop)

    @staticmethod
    def _shape_sig(x) -> tuple | None:
        """Stack-compatibility signature: leading axis is the batch
        axis, everything else must match."""
        if not hasattr(x, "shape") or not hasattr(x, "dtype"):
            return None
        if len(x.shape) < 1:
            return None
        return (x.shape[1:], str(x.dtype))

    def _route(self, module: str, stage: _Stage) -> str | None:
        # _charge() writes _free_at under the lock from concurrent
        # drains; route against a consistent snapshot, not the live map
        with self._lock:
            device_free = dict(self._free_at)
        return self.engine.route_module(
            module, device_free=device_free, ready_time=self._now(),
            source=stage.request.source, request=stage.request)

    def _charge(self, module: str, host: str | None, k: int,
                t_dispatch: float) -> None:
        """Advance the host's predicted busy-until by the footnote-4
        batched-call estimate — the scheduler-side mirror of the
        simulator's device_free bookkeeping."""
        eng = self.engine
        spec = eng.registry.modules.get(module)
        if host is None or eng.cluster is None or spec is None:
            return
        try:
            dev = eng.cluster.device(host)
        except KeyError:
            return
        t_est = eng.cluster.t_comp(spec, dev) * batch_factor(k)
        with self._lock:
            self._free_at[host] = max(self._free_at.get(host, 0.0),
                                      t_dispatch) + t_est

    def _bookkeep(self, module: str, batch: list[_Stage]) -> None:
        mt = self.metrics
        mt.counter("serve.calls", module=module).inc()
        mt.counter("serve.stages", module=module).inc(len(batch))
        mt.histogram("serve.batch_occupancy", module=module).observe(
            len(batch))
        if len({s.request.model for s in batch}) >= 2:
            mt.counter("serve.cross_task_batches", module=module).inc()

    def _finish_metrics(self, result: InferenceResult,
                        request: Request) -> None:
        """Per-task latency histogram + SLO hit/miss — what powers
        ``obs.summary.slo_summary``."""
        mt = self.metrics
        mt.histogram("request.latency_s", model=result.model).observe(
            result.latency_s)
        if request.slo_deadline is not None:
            met = result.latency_s <= request.slo_deadline
            mt.counter("slo.hit" if met else "slo.miss",
                       model=result.model).inc()

    def _run_encoder_batch(self, module: str, batch: list[_Stage],
                           t_pop: float) -> None:
        host = self._route(module, batch[0])
        t0 = self._now()
        if len(batch) == 1:
            out, used = self.engine.apply_module(module, batch[0].x,
                                                 host=host)
            outs = [out]
        else:
            xs = [jnp.asarray(s.x) for s in batch]
            sizes = np.cumsum([x.shape[0] for x in xs])[:-1]
            out, used = self.engine.apply_module(
                module, jnp.concatenate(xs, axis=0), host=host)
            outs = jnp.split(out, sizes, axis=0)   # async: no block here
        self._charge(module, used, len(batch), t0)
        self._bookkeep(module, batch)
        t1 = self._now()
        modality = self.engine.registry.modules[module].modality
        models = sorted({s.request.model for s in batch})
        # per-request bookkeeping under the lock: two encoder batches
        # finishing concurrently for the same request must not both see
        # an empty pending set and double-enqueue the head.  Ready heads
        # are collected and submitted after release (stream construction
        # and head enqueue do their own locking).
        ready: list[tuple[_Stage, dict[str, Any], int]] = []
        for s, o in zip(batch, outs):
            with self._lock:
                fl = self.inflight[s.rid]
                root = fl.root_sid
            self.tracer.record(module, "batch", t_pop, t0, rid=s.rid,
                               parent=root, batch=len(batch),
                               models=models)
            span = self.tracer.record(
                module, "encode", t0, t1, rid=s.rid, parent=root,
                host=used, batch=len(batch), models=models,
                cross_task=len(models) >= 2)
            with self._lock:
                fl.enc_outputs[modality] = o
                if used:
                    fl.devices[module] = used
                fl.timeline.append(span)
                fl.pending.discard(module)
                if not fl.pending:
                    ready.append((s, dict(fl.enc_outputs), root))
        for s, enc_outputs, root in ready:
            head = self.engine.registry.models[s.request.model].head
            if head.generative:
                stream = self._ensure_stream(head.name)
                stream.submit(s.rid, s.request, enc_outputs, parent=root)
            else:
                self._enqueue(_Stage(s.rid, head.name, s.request))

    def _service_decode(self, module: str, stream: DecodeStream) -> None:
        """One decode-stream service round: admissions + one batched
        decode step, then results for the sequences that finished."""
        report = stream.tick()
        host = self.engine.decoder_runtime(module).host
        if report.decode_batch:
            self._charge(module, host, report.decode_batch, self._now())
        for seq in report.finished:
            with self._lock:
                fl = self.inflight.pop(seq.rid)
            fl.timeline.extend(seq.timeline)
            if host:
                fl.devices[module] = host
            enc = {k: jax.block_until_ready(v)
                   for k, v in fl.enc_outputs.items()}
            t_end = self._now()
            result = InferenceResult(
                model=seq.request.model,
                output=np.asarray(seq.tokens, np.int32),
                encoder_outputs=enc, timeline=fl.timeline,
                latency_s=t_end - fl.t_admit, devices=fl.devices,
                rid=seq.rid)
            self.tracer.end(fl.root_sid, t1=t_end,
                            n_tokens=len(seq.tokens))
            self._finish_metrics(result, seq.request)
            with self._lock:
                self.results[seq.rid] = result
            if self.on_finish is not None:
                self.on_finish(result)

    def _run_head(self, module: str, stage: _Stage, t_pop: float) -> None:
        with self._lock:
            fl = self.inflight.pop(stage.rid)
        host = self._route(module, stage)
        t0 = self._now()
        out, used = self.engine.apply_head(
            module, fl.enc_outputs, stage.request.head_extra, host=host)
        out = jax.block_until_ready(out)
        self._charge(module, used, 1, t0)
        self._bookkeep(module, [stage])
        t1 = self._now()
        self.tracer.record(module, "batch", t_pop, t0, rid=stage.rid,
                           parent=fl.root_sid, batch=1)
        span = self.tracer.record(module, "head", t0, t1, rid=stage.rid,
                                  parent=fl.root_sid, host=used)
        if used:
            fl.devices[module] = used
        fl.timeline.append(span)
        fl.enc_outputs = {k: jax.block_until_ready(v)
                          for k, v in fl.enc_outputs.items()}
        result = InferenceResult(
            model=stage.request.model, output=out,
            encoder_outputs=fl.enc_outputs, timeline=fl.timeline,
            latency_s=t1 - fl.t_admit, devices=fl.devices, rid=stage.rid)
        self.tracer.end(fl.root_sid, t1=t1)
        self._finish_metrics(result, stage.request)
        with self._lock:
            self.results[stage.rid] = result
        if self.on_finish is not None:
            self.on_finish(result)


def lm_scheduler(bundle, params=None, *, config: SchedulerConfig | None = None,
                 on_finish=None) -> ServeScheduler:
    """Single-bundle convenience: wrap one LM ``ModelBundle`` as a
    head-only generative model ("lm") on a bare engine and return a
    ``ServeScheduler`` serving it through the paged decode substrate.
    Submit ``Request(model="lm", prompt=..., ...)``; precomputed
    modality features (VLM image embeds) go in ``inputs``."""
    import jax

    from repro.core.module import ModelSpec, ModuleSpec

    name = getattr(bundle.cfg, "name", "lm-head")
    head = ModuleSpec(name, "head", "task", bundle.param_count(),
                      generative=True)
    model = ModelSpec("lm", "generation", (), head)
    engine = S2M3Engine()
    if params is None:
        params = bundle.init(jax.random.PRNGKey(0))
    engine.deploy_model(model, {name: (lambda: (bundle, params))})
    return ServeScheduler(engine, config=config, on_finish=on_finish)
