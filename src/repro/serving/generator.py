"""Continuous-batching LM server.

Requests (prompt token lists) are admitted into free KV-cache slots via
a batch-1 prefill + scatter; live slots decode together in one batched
``decode_step``; finished sequences free their slots for waiting
requests.  This is the task-head serving loop the S2M3 engine drives for
decoder-head models — and the module-level batching the paper sketches
in §VI-C, made concrete.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.kvcache import SlotPool, insert_sequence
from repro.serving.sampler import sample


@dataclasses.dataclass
class GenRequest:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    eos_id: int = -1           # -1: never stop early
    # filled by the server:
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    extras: dict = dataclasses.field(default_factory=dict)  # modality stubs


class LMServer:
    def __init__(self, bundle, *, max_batch: int = 4, cache_len: int = 256,
                 seed: int = 0, params=None):
        self.bundle = bundle
        self.cfg = bundle.cfg
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.params = params if params is not None else bundle.init(
            jax.random.PRNGKey(seed))
        self.pool = SlotPool(max_batch)
        self.cache = bundle.init_cache(max_batch, cache_len, dtype=jnp.float32)
        self._slot_req: dict[int, GenRequest] = {}
        self._queue: deque[GenRequest] = deque()
        self._rng = jax.random.PRNGKey(seed + 1)
        self._prefill = jax.jit(bundle.prefill)
        self._decode = jax.jit(bundle.decode_step, donate_argnums=(2,))
        self._steps = 0

    # -- client API -----------------------------------------------------
    def submit(self, req: GenRequest):
        self._queue.append(req)

    def run(self, max_steps: int = 10_000) -> list[GenRequest]:
        finished = []
        while (self._queue or self.pool.n_live) and max_steps > 0:
            max_steps -= 1
            self._admit()
            finished.extend(self._step())
        return finished

    # -- internals ------------------------------------------------------
    def _admit(self):
        while self._queue and self.pool._free:
            req = self._queue.popleft()
            slot = self.pool.alloc()
            one = self.bundle.init_cache(1, self.cache_len, dtype=jnp.float32)
            batch = {"tokens": jnp.asarray([req.prompt], jnp.int32), **{
                k: jnp.asarray(v)[None] for k, v in req.extras.items()}}
            logits, one = self._prefill(self.params, batch, one)
            self.cache = insert_sequence(self.cache, one, slot)
            n_prefix = (self.cfg.n_image_tokens
                        if self.cfg.has_vision_stub else 0)
            self.pool.lengths[slot] = len(req.prompt) + n_prefix
            self._slot_req[slot] = req
            tok = self._pick(logits[0], req)
            req.output.append(int(tok))

    def _step(self):
        finished = []
        if self.pool.n_live == 0:
            return finished
        tokens = np.zeros((self.max_batch, 1), np.int32)
        lengths = np.zeros((self.max_batch,), np.int32)
        for s, req in self._slot_req.items():
            tokens[s, 0] = req.output[-1]
            lengths[s] = self.pool.lengths[s]
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tokens), self.cache, jnp.asarray(lengths))
        self._steps += 1
        for s in list(self._slot_req):
            req = self._slot_req[s]
            self.pool.lengths[s] += 1
            if self.pool.lengths[s] >= self.cache_len - 1:
                req.done = True
            else:
                tok = int(self._pick(logits[s], req))
                req.output.append(tok)
                if tok == req.eos_id or len(req.output) >= req.max_new_tokens:
                    req.done = True
            if req.done:
                finished.append(req)
                del self._slot_req[s]
                self.pool.release(s)
        return finished

    def _pick(self, logits, req: GenRequest):
        if req.temperature <= 0:
            return jnp.argmax(logits, -1)
        self._rng, k = jax.random.split(self._rng)
        return sample(logits[None], k, temperature=req.temperature)[0]
