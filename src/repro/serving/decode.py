"""Paged continuous-batching decode streams — the generative half of the
serving scheduler.

One ``DecodeStream`` per generative decoder module: it owns the module's
page pool (``PagePool``), the fixed-width decode rows (``SlotPool``),
and the paged KV cache the engine decodes against.  Requests arrive from
``ServeScheduler`` after their encoder stages complete; each is admitted
into a free row via a batch-1 prefill scattered into freshly allocated
pages, then all live rows — across *tasks*, this is the S2M3 sharing
argument applied to generative heads — decode together in one batched
``paged_decode_attention`` launch per step.

Admission reserves each sequence's worst-case page count up front
(``n_prefix + len(prompt) + max_new_tokens``), so mid-stream ``extend``
can never fail and no preemption is needed; the waiting queue is ordered
by SLO deadline (earliest first), then arrival.  Dead rows point their
block-table entries at a reserved dummy page (page 0), so the batched
scatter never corrupts a live sequence.

Lock discipline (enforced by ``repro.analysis.concurrency_lint``): all
allocator calls and shared-state mutation happen under ``self._lock``;
prefill/decode dispatch happens outside it.  A tick-level busy flag
keeps concurrent ``tick()`` calls from interleaving device steps.
"""

from __future__ import annotations

import heapq
import threading
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.routing import Request
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer
from repro.serving.kvcache import PagePool, SlotPool, insert_pages
from repro.serving.sampler import select_token

_DUMMY = "<dummy>"


@dataclass
class _GenSeq:
    """One generative request's decode state."""

    rid: int
    request: Request
    enc_outputs: dict[str, Any]
    t_submit: float
    tokens: list[int] = field(default_factory=list)
    row: int = -1
    length: int = 0                 # tokens currently in the paged cache
    rng: Any = None
    done: bool = False
    timeline: list = field(default_factory=list)
    parent: int | None = None       # root span of the owning request
    wait_sid: int = -1              # admission-wait span
    decode_sid: int = -1            # decode-residency span (tick parent)


@dataclass
class TickReport:
    finished: list[_GenSeq]
    prefills: int = 0
    decode_batch: int = 0


class DecodeStream:
    """Continuous-batching decode state for one generative module."""

    def __init__(self, engine, module: str, *, rows: int, n_pages: int,
                 page_size: int, max_seq_len: int, now=None,
                 tracer: Tracer | None = None,
                 metrics: MetricsRegistry | None = None):
        self.engine = engine
        self.module = module
        self.rt = engine.decoder_runtime(module)
        self.page_size = page_size
        self.max_seq_len = max_seq_len
        self.n_max = -(-max_seq_len // page_size)
        self._now = now or (lambda: 0.0)
        # standalone streams get their own registry/tracer; under a
        # ServeScheduler both are shared so stats and traces are unified
        self.metrics = metrics or MetricsRegistry()
        self.tracer = tracer or Tracer(clock=self._now)
        self.pool = PagePool(n_pages, page_size, metrics=self.metrics,
                             module=module)
        self.rows = SlotPool(rows)
        self.cache = engine.init_paged_cache(module, n_pages, page_size,
                                             jnp.float32)
        self._lock = threading.RLock()
        with self._lock:
            # page 0 is the dummy target for dead rows' scatters
            self.pool.alloc(_DUMMY, 1)
        self.waiting: list = []           # heap: (deadline, t, n, seq)
        self._n_submitted = 0
        self.live: dict[int, _GenSeq] = {}
        self.tables = np.zeros((rows, self.n_max), np.int32)
        self.lengths = np.zeros((rows,), np.int32)
        self._worst: dict[int, int] = {}  # rid -> reserved worst pages
        self._reserved = 0
        self._busy = False
        # counters (read via the int properties / stats_dict)
        self._c_steps = self.metrics.counter("decode.steps", module=module)
        self._c_tokens = self.metrics.counter("decode.tokens", module=module)
        self._c_prefills = self.metrics.counter("decode.prefills",
                                                module=module)
        self._c_xtask = self.metrics.counter("decode.cross_task_batches",
                                             module=module)

    # legacy counter attributes, now views over the metrics registry
    @property
    def decode_steps(self) -> int:
        return int(self._c_steps.value)

    @property
    def decode_tokens(self) -> int:
        return int(self._c_tokens.value)

    @property
    def prefills(self) -> int:
        return int(self._c_prefills.value)

    @property
    def cross_task_decode_batches(self) -> int:
        return int(self._c_xtask.value)

    # -- sizing ---------------------------------------------------------
    def _worst_tokens(self, request: Request) -> int:
        return (self.rt.n_prefix + len(request.prompt)
                + max(int(request.max_new_tokens), 1))

    def validate(self, request: Request) -> None:
        if request.prompt is None or len(request.prompt) == 0:
            raise ValueError(
                f"generative request {request.rid} has no prompt tokens")
        worst = self._worst_tokens(request)
        if worst > self.max_seq_len:
            raise ValueError(
                f"request {request.rid}: prefix+prompt+max_new_tokens="
                f"{worst} exceeds max_seq_len={self.max_seq_len} of "
                f"decoder {self.module!r}")
        with self._lock:
            need = self.pool.pages_for(worst)
            usable = self.pool.n_pages - 1
        if need > usable:
            raise ValueError(
                f"request {request.rid}: needs {need} pages, pool holds "
                f"{usable} usable")

    # -- admission ------------------------------------------------------
    def depth(self) -> int:
        with self._lock:
            return len(self.waiting) + len(self.live)

    def submit(self, rid: int, request: Request,
               enc_outputs: dict[str, Any],
               parent: int | None = None) -> None:
        self.validate(request)
        seq = _GenSeq(rid, request, enc_outputs, self._now(), parent=parent)
        seq.wait_sid = self.tracer.begin(self.module, "admission", rid=rid,
                                         parent=parent)
        deadline = (request.slo_deadline if request.slo_deadline is not None
                    else float("inf"))
        with self._lock:
            heapq.heappush(self.waiting,
                           (deadline, seq.t_submit, self._n_submitted, seq))
            self._n_submitted += 1

    def _outstanding_pages(self) -> int:
        """Reserved-but-not-yet-held pages across live sequences."""
        held = self.pool.n_live_pages - 1          # minus the dummy page
        return self._reserved - held

    def _pop_admittable(self) -> _GenSeq | None:
        """Admit the head of the waiting queue if a row and its
        worst-case page reservation fit; head-of-line order keeps the
        SLO-deadline priority honest.  Takes the (re-entrant) lock
        itself so allocator calls are locked at every call site."""
        with self._lock:
            if not self.waiting:
                return None
            seq = self.waiting[0][3]
            worst = self.pool.pages_for(self._worst_tokens(seq.request))
            if self.pool.n_free - self._outstanding_pages() < worst:
                return None
            row = self.rows.alloc()
            if row is None:
                return None
            heapq.heappop(self.waiting)
            prefix_len = self.rt.n_prefix + len(seq.request.prompt)
            pages = self.pool.alloc(seq.rid, prefix_len)
            seq.row = row
            seq.length = prefix_len
            self._worst[seq.rid] = worst
            self._reserved += worst
            self.tables[row, :] = 0
            self.tables[row, :len(pages)] = pages
            self.lengths[row] = prefix_len
            self.live[row] = seq
            self.tracer.end(seq.wait_sid)
            return seq

    def _finish_locked(self, seq: _GenSeq) -> None:
        with self._lock:
            seq.done = True
            self.pool.free(seq.rid)
            self.rows.release(seq.row)
            del self.live[seq.row]
            self.tables[seq.row, :] = 0
            self.lengths[seq.row] = 0
            self._reserved -= self._worst.pop(seq.rid)

    # -- execution ------------------------------------------------------
    def _prefill(self, seq: _GenSeq) -> None:
        """Batch-1 prefill into the sequence's pages + first token.
        Device dispatch — runs outside the lock."""
        req = seq.request
        with self._lock:
            pages = self.pool.block_table(seq.rid)
        span = len(pages) * self.page_size
        one = self.rt.bundle.init_cache(1, span, jnp.float32)
        t0 = self._now()
        batch = self.engine.gen_batch(req.prompt, seq.enc_outputs)
        logits, one = self.engine.apply_prefill(self.module, batch, one)
        self.cache = insert_pages(self.cache, one, pages, seq.length)
        seq.rng = jax.random.PRNGKey((seq.rid or 0) & 0x7FFFFFFF)
        seq.rng, k = jax.random.split(seq.rng)
        tok = int(select_token(logits[0], k, temperature=req.temperature))
        seq.tokens.append(tok)
        span = self.tracer.record(self.module, "prefill", t0, self._now(),
                                  rid=seq.rid, parent=seq.parent,
                                  prompt_tokens=len(req.prompt),
                                  prefix_len=seq.length)
        seq.timeline.append(span)
        self._c_prefills.inc()

    def _seq_done(self, seq: _GenSeq) -> bool:
        req = seq.request
        return (len(seq.tokens) >= max(int(req.max_new_tokens), 1)
                or seq.tokens[-1] == req.eos_id)

    def _admit_all(self) -> list[_GenSeq]:
        finished = []
        while True:
            with self._lock:
                seq = self._pop_admittable()
            if seq is None:
                break
            try:
                self._prefill(seq)
            except Exception:
                # a failed prefill must not strand the admitted row,
                # its pages, or the worst-case reservation — the leak
                # the model checker's pages/no-leak invariant flags
                with self._lock:
                    self._finish_locked(seq)
                raise
            if self._seq_done(seq):
                with self._lock:
                    self._finish_locked(seq)
                finished.append(seq)
            else:
                # residency span: every decode tick of this sequence
                # parents under it
                seq.decode_sid = self.tracer.begin(
                    self.module, "decode", rid=seq.rid, parent=seq.parent)
        return finished

    def _decode_once(self) -> tuple[list[_GenSeq], int]:
        """One batched decode step over all live rows.  Batch formation
        (incl. page extension) under the lock; dispatch outside it."""
        with self._lock:
            tokens = np.zeros((self.rows.max_slots, 1), np.int32)
            live = sorted(self.live.items())
            if not live:
                return [], 0
            for row, seq in live:
                # the step inserts at position length: make sure the
                # owning page exists (reservation guarantees success)
                added = self.pool.extend(seq.rid, seq.length + 1)
                if added:
                    table = self.pool.block_table(seq.rid)
                    self.tables[row, :len(table)] = table
                tokens[row, 0] = seq.tokens[-1]
            tables = self.tables.copy()
            lengths = self.lengths.copy()
            pages_live = self.pool.n_live_pages
            self._c_steps.inc()
            if len({seq.request.model for _, seq in live}) >= 2:
                self._c_xtask.inc()
        t0 = self._now()
        logits, cache = self.engine.apply_paged_decode(
            self.module, jnp.asarray(tokens), self.cache,
            jnp.asarray(tables), jnp.asarray(lengths))
        self.cache = cache
        picks: dict[int, int] = {}
        for row, seq in live:
            seq.rng, k = jax.random.split(seq.rng)
            picks[row] = int(select_token(
                logits[row], k, temperature=seq.request.temperature))
        t1 = self._now()
        for row, seq in live:
            self.tracer.record(self.module, "decode_tick", t0, t1,
                               rid=seq.rid, parent=seq.decode_sid,
                               rows=len(live), pages_live=pages_live)
        finished = []
        with self._lock:
            for row, seq in live:
                seq.length += 1
                self.lengths[row] = seq.length
                self.pool.used_tokens[seq.rid] = seq.length
                seq.tokens.append(picks[row])
                self._c_tokens.inc()
                if self._seq_done(seq):
                    seq.timeline.append(
                        self.tracer.end(seq.decode_sid, t1=self._now()))
                    self._finish_locked(seq)
                    finished.append(seq)
        return finished, len(live)

    def tick(self) -> TickReport:
        """One scheduler service round: admit what fits, then one
        batched decode step.  Returns the finished sequences."""
        with self._lock:
            if self._busy:
                return TickReport([], 0, 0)
            self._busy = True
        try:
            p0 = self.prefills
            finished = self._admit_all()
            prefills = self.prefills - p0
            more, batch = self._decode_once()
            return TickReport(finished + more, prefills, batch)
        finally:
            with self._lock:
                self._busy = False

    # -- introspection ---------------------------------------------------
    def state_view(self):
        """Snapshot this stream as a ``repro.analysis.invariants``
        ``StateView`` so the runtime-tagged invariant subset can be
        evaluated against live serving state (see
        ``ServeScheduler.check_invariants``)."""
        from repro.analysis.invariants import SeqView, StateView, WaitView
        with self._lock:
            free = set(self.pool._free)
            owners: dict[int, object] = {}
            multi: list[int] = []
            for rid, pages in self.pool.tables.items():
                for p in pages:
                    if p in owners or p in free:
                        multi.append(p)
                    owners[p] = rid
            live = tuple(
                SeqView(
                    rid=seq.rid,
                    held_pages=len(self.pool.tables.get(seq.rid, ())),
                    worst_pages=self._worst.get(seq.rid, 0),
                    remaining_tokens=max(
                        int(seq.request.max_new_tokens) - len(seq.tokens), 0),
                    deadline=(seq.request.slo_deadline
                              if seq.request.slo_deadline is not None
                              else float("inf")),
                    model=seq.request.model)
                for _, seq in sorted(self.live.items()))
            waiting = tuple(
                WaitView(rid=seq.rid,
                         worst_pages=self.pool.pages_for(
                             self._worst_tokens(seq.request)),
                         deadline=deadline, model=seq.request.model)
                for deadline, _, _, seq in sorted(self.waiting))
            return StateView(
                pages_total=self.pool.n_pages,
                pages_free=self.pool.n_free,
                page_owners=owners,
                page_multiowner=tuple(multi),
                page_size=self.page_size,
                rows_total=self.rows.max_slots,
                rows_live=self.rows.n_live,
                live=live,
                waiting=waiting,
                terminal=not self.live and not self.waiting,
            )

    # -- stats ----------------------------------------------------------
    def stats_dict(self) -> dict[str, Any]:
        with self._lock:
            frag = self.pool.fragmentation()
            return {
                "decode_steps": self.decode_steps,
                "decode_tokens": self.decode_tokens,
                "prefills": self.prefills,
                "cross_task_decode_batches": self.cross_task_decode_batches,
                "decode_rows": self.rows.max_slots,
                "live_rows": len(self.live),
                "waiting": len(self.waiting),
                "pages_total": frag["pages_total"],
                "pages_live": frag["pages_live"],
                "pages_peak": frag["pages_peak"],
                "page_occupancy": round(
                    frag["pages_live"] / frag["pages_total"], 4),
                "internal_frag": frag["internal_frag"],
            }
