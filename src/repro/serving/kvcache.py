"""Paged KV-cache allocation for continuous batching.

The decode cache is a global pool of fixed-size pages — every
attention-cache leaf is ``(layers, n_pages, page_size, ...)`` — and
``PagePool`` hands out pages and maintains the per-sequence *block
tables* that the paged ``decode_attention`` kernel consumes.  Pages are
recycled LIFO so a hot working set stays small; ``fragmentation()``
reports how much of the live pages' token capacity is actually filled
(internal fragmentation is the price of fixed-size paging).

``SlotPool`` remains as the *row* allocator: the batched decode launch
has a fixed leading batch axis, and each live sequence owns one row in
it (tokens/lengths/table rows).  Both allocators guard against
double-free — releasing a non-live slot/sequence raises instead of
corrupting the free list (previously two requests could be handed the
same slot).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class PagesExhausted(RuntimeError):
    """The page pool cannot satisfy an allocation; admission control
    should have prevented this — treat it as a scheduler bug."""


class SlotPool:
    """Fixed-capacity batch-row allocator with a double-free guard."""

    def __init__(self, max_slots: int):
        self.max_slots = max_slots
        self._free = list(range(max_slots))[::-1]
        self.lengths = [0] * max_slots
        self.live = [False] * max_slots

    def alloc(self) -> int | None:
        if not self._free:
            return None
        s = self._free.pop()
        self.live[s] = True
        return s

    def release(self, slot: int):
        if not self.live[slot]:
            raise ValueError(
                f"SlotPool.release: slot {slot} is not live (double "
                "free would hand the same slot to two requests)")
        self.live[slot] = False
        self.lengths[slot] = 0
        self._free.append(slot)

    @property
    def n_live(self) -> int:
        return sum(self.live)


class PagePool:
    """Fixed-size KV pages + per-sequence block tables.

    ``alloc(seq, n_tokens)`` claims enough pages for ``n_tokens``;
    ``extend(seq, new_len)`` grows a live sequence's table as decode
    crosses page boundaries; ``free(seq)`` returns the pages (guarded
    against double free).  ``used_tokens`` tracks the filled prefix of
    each sequence so ``fragmentation()`` can report internal slack.
    """

    def __init__(self, n_pages: int, page_size: int, *, metrics=None,
                 **labels):
        if n_pages < 1 or page_size < 1:
            raise ValueError("n_pages and page_size must be >= 1")
        self.n_pages = n_pages
        self.page_size = page_size
        self._free = list(range(n_pages))[::-1]
        self.tables: dict[object, list[int]] = {}
        self.used_tokens: dict[object, int] = {}
        self.pages_peak = 0
        # optional obs.metrics registry: page-occupancy gauges + alloc
        # counters, labelled by the owning decode stream's module
        self._metrics = metrics
        self._labels = dict(labels)
        self._note()

    def _note(self) -> None:
        if self._metrics is None:
            return
        self._metrics.gauge("pagepool.pages_live",
                            **self._labels).set(self.n_live_pages)
        self._metrics.gauge("pagepool.pages_peak",
                            **self._labels).set(self.pages_peak)

    # -- capacity -------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live_pages(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def n_seqs(self) -> int:
        return len(self.tables)

    def pages_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 0) // self.page_size) if n_tokens else 0

    def can_alloc(self, n_tokens: int) -> bool:
        return self.pages_for(max(n_tokens, 1)) <= len(self._free)

    # -- lifecycle ------------------------------------------------------
    def alloc(self, seq, n_tokens: int) -> list[int]:
        """Claim pages for a new sequence holding ``n_tokens``; at least
        one page is always allocated so the block table is never empty."""
        if seq in self.tables:
            raise ValueError(f"PagePool.alloc: sequence {seq!r} already live")
        need = max(self.pages_for(n_tokens), 1)
        if need > len(self._free):
            raise PagesExhausted(
                f"PagePool.alloc: need {need} pages for {seq!r}, only "
                f"{len(self._free)} free of {self.n_pages}")
        pages = [self._free.pop() for _ in range(need)]
        self.tables[seq] = pages
        self.used_tokens[seq] = max(n_tokens, 0)
        self.pages_peak = max(self.pages_peak, self.n_live_pages)
        if self._metrics is not None:
            self._metrics.counter("pagepool.page_allocs",
                                  **self._labels).inc(need)
        self._note()
        return pages

    def extend(self, seq, new_len: int) -> list[int]:
        """Grow a live sequence to ``new_len`` tokens; returns the pages
        added (possibly empty when the current tail page still has room)."""
        pages = self.tables.get(seq)
        if pages is None:
            raise ValueError(f"PagePool.extend: sequence {seq!r} not live")
        need = max(self.pages_for(new_len), 1) - len(pages)
        if need > len(self._free):
            raise PagesExhausted(
                f"PagePool.extend: need {need} more pages for {seq!r}, "
                f"only {len(self._free)} free of {self.n_pages}")
        added = [self._free.pop() for _ in range(max(need, 0))]
        pages.extend(added)
        self.used_tokens[seq] = max(self.used_tokens[seq], new_len)
        self.pages_peak = max(self.pages_peak, self.n_live_pages)
        if added and self._metrics is not None:
            self._metrics.counter("pagepool.page_allocs",
                                  **self._labels).inc(len(added))
        self._note()
        return added

    def free(self, seq) -> None:
        """Return a sequence's pages to the pool.  Raises on a sequence
        that is not live — the SlotPool double-free guard, ported."""
        pages = self.tables.pop(seq, None)
        if pages is None:
            raise ValueError(
                f"PagePool.free: sequence {seq!r} is not live (double "
                "free would hand the same pages to two sequences)")
        self.used_tokens.pop(seq, None)
        self._free.extend(reversed(pages))
        if self._metrics is not None:
            self._metrics.counter("pagepool.seq_frees",
                                  **self._labels).inc()
        self._note()

    # -- views ----------------------------------------------------------
    def block_table(self, seq) -> list[int]:
        return list(self.tables[seq])

    def table_array(self, seqs, n_max: int) -> np.ndarray:
        """(len(seqs), n_max) int32 block-table array for the paged
        kernel; missing/short rows pad with 0 (masked by lengths)."""
        out = np.zeros((len(seqs), n_max), np.int32)
        for i, seq in enumerate(seqs):
            pages = self.tables.get(seq, ())
            if len(pages) > n_max:
                raise ValueError(
                    f"PagePool.table_array: sequence {seq!r} owns "
                    f"{len(pages)} pages > n_max={n_max}")
            out[i, :len(pages)] = pages
        return out

    def fragmentation(self) -> dict:
        """Internal-fragmentation accounting: how much of the live
        pages' token capacity is actually filled."""
        live = self.n_live_pages
        cap = live * self.page_size
        used = sum(self.used_tokens.values())
        return {
            "pages_total": self.n_pages,
            "pages_free": len(self._free),
            "pages_live": live,
            "pages_peak": self.pages_peak,
            "tokens_capacity": cap,
            "tokens_used": used,
            "slack_tokens": cap - used,
            "internal_frag": round(1.0 - used / cap, 4) if cap else 0.0,
        }


# ---------------------------------------------------------------------------
# cache pytree helpers
# ---------------------------------------------------------------------------

def insert_sequence(big_cache, one_cache, slot: int):
    """Scatter a batch-1 cache into slot `slot` of a pooled dense cache.

    Leaves are (layers, batch, ...): axis 1 indexes the slot.
    """
    def one(big, single):
        return big.at[:, slot].set(single[:, 0].astype(big.dtype))

    return jax.tree.map(one, big_cache, one_cache)


def insert_pages(paged_cache, one_cache, page_ids, n_tokens: int):
    """Scatter a batch-1 *dense* prefill cache into the page pool.

    Paged leaves are (layers, n_pages, page_size, ...); dense leaves
    are (layers, 1, T, ...) with T >= the pages' token span.  The first
    ``len(page_ids) * page_size`` positions are copied page-by-page;
    garbage past ``n_tokens`` lands in the owned pages' tails, where the
    length mask hides it.
    """
    ids = jnp.asarray(page_ids, jnp.int32)

    def one(pages, dense):
        ps = pages.shape[2]
        span = len(page_ids) * ps
        chunks = dense[:, 0, :span].reshape(
            dense.shape[0], len(page_ids), ps, *dense.shape[3:])
        return pages.at[:, ids].set(chunks.astype(pages.dtype))

    return jax.tree.map(one, paged_cache, one_cache)


def blank_like(cache):
    return jax.tree.map(jnp.zeros_like, cache)
