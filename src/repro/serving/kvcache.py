"""KV-cache slot pool for continuous batching.

The decode cache is a fixed (layers, max_batch, cache_len, ...) pytree;
``SlotPool`` tracks which batch slots are live and scatters a freshly
prefetched single-sequence cache into a slot (axis 1 = batch on every
leaf, by construction of cache_specs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


class SlotPool:
    def __init__(self, max_slots: int):
        self.max_slots = max_slots
        self._free = list(range(max_slots))[::-1]
        self.lengths = [0] * max_slots
        self.live = [False] * max_slots

    def alloc(self) -> int | None:
        if not self._free:
            return None
        s = self._free.pop()
        self.live[s] = True
        return s

    def release(self, slot: int):
        self.live[slot] = False
        self.lengths[slot] = 0
        self._free.append(slot)

    @property
    def n_live(self) -> int:
        return sum(self.live)


def insert_sequence(big_cache, one_cache, slot: int):
    """Scatter a batch-1 cache into slot `slot` of the pooled cache.

    Leaves are (layers, batch, ...): axis 1 indexes the slot.
    """
    def one(big, single):
        return big.at[:, slot].set(single[:, 0].astype(big.dtype))

    return jax.tree.map(one, big_cache, one_cache)


def blank_like(cache):
    return jax.tree.map(jnp.zeros_like, cache)
