"""Serving runtime: KV-cache slots, samplers, continuous batching,
and the S2M3 multi-task engine."""
