"""Serving runtime: KV-cache slots, samplers, LM continuous batching
(generator), the S2M3 multi-task engine, and the cross-task
continuous-batching scheduler (scheduler.ServeScheduler) behind
``s2m3.Deployment.serve()``."""
