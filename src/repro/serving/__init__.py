"""Serving runtime: paged KV-cache pools (kvcache), samplers, the
per-module decode streams behind continuous batching (decode), the S2M3
multi-task engine, and the cross-task continuous-batching scheduler
(scheduler.ServeScheduler) behind ``s2m3.Deployment.serve()``.
Generative and encoder traffic share one scheduler: encoder stages
coalesce into cross-task batches, generative heads decode all live
sequences in one batched paged-attention launch per step."""
