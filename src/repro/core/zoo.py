"""ModelSpecs for the paper's 14-model zoo and the 10 assigned archs.

The zoo feeds the placement/routing simulator (exact published param
counts); ``arch_model_spec`` adapts an assigned ``ArchConfig`` into the
same ModelSpec language so the assigned architectures participate in
S2M3 placement/sharing.  Notably tinyllama-1.1b carries the *same
signature* as the paper's Flint-v0.5-1B head, so cross-registry sharing
actually triggers.
"""

from __future__ import annotations

from repro.common.config import ArchConfig
from repro.configs.s2m3_zoo import MODULE_PARAMS, ZOO
from repro.core.module import ModelSpec, ModuleSpec
from repro.core.profiles import TOKENS_PER_QUERY


def _modality(module_name: str) -> str:
    n = module_name
    if n.startswith(("resnet", "vit", "openclip-vit")):
        return "vision"
    if "trf" in n:
        return "text"
    if n.startswith("audio"):
        return "audio"
    return "task"


def _module(name: str, kind: str) -> ModuleSpec:
    modality = _modality(name) if kind == "encoder" else "task"
    n_params = MODULE_PARAMS[name]
    tokens = TOKENS_PER_QUERY[modality]
    input_bytes = {"vision": 600_000, "text": 1_000, "audio": 960_000,
                   "task": 8_192}[modality]
    return ModuleSpec(
        name=name, kind=kind, modality=modality, n_params=n_params,
        bytes_per_param=4.0,   # the paper deploys fp32 checkpoints
        flops_per_query=2.0 * n_params * tokens,
        input_bytes=input_bytes,
        output_bytes=4_096,
    )


# per-task request work multiplicity (see core.profiles: retrieval =
# zero-shot classification over ~100 candidate prompts)
TASK_WORK: dict[str, tuple[tuple[str, float], ...]] = {
    "retrieval": (("text", 100.0),),
    "classification": (),
    "vqa-enc": (),
    "vqa-dec": (),
    "alignment": (),
    "captioning": (),
}


def request_for(model: ModelSpec, rid: int, source: str, arrival: float = 0.0,
                batch: int = 1):
    from repro.core.routing import Request

    return Request(rid, model.name, source, arrival, batch,
                   work=TASK_WORK.get(model.task, ()))


def paper_zoo() -> dict[str, ModelSpec]:
    out = {}
    for mdl_name, (task, encoders, head) in ZOO.items():
        out[mdl_name] = ModelSpec(
            name=mdl_name, task=task,
            encoders=tuple(_module(e, "encoder") for e in encoders),
            head=_module(head, "head"),
        )
    return out


def arch_model_spec(cfg: ArchConfig) -> ModelSpec:
    """Assigned architecture -> S2M3 ModelSpec.

    Multi-modal archs split into encoder+head; pure text LMs are
    head-only models (the paper's own characterization of decoder-only
    VQA: no parallel-routing benefit, full sharing benefit).
    """
    from repro.layers.initializers import spec_param_count
    from repro.models.api import build_model

    bundle = build_model(cfg)
    n_total = bundle.param_count()

    def lm_head(n) -> ModuleSpec:
        # sharing requires identical signatures: when the arch is also a
        # zoo module (tinyllama-1.1b == the Flint VQA head), reuse the
        # zoo's canonical spec so the registry dedups
        if cfg.name in MODULE_PARAMS:
            return _module(cfg.name, "head")
        return ModuleSpec(
            name=cfg.name, kind="head", modality="task", n_params=n,
            bytes_per_param=4.0,
            flops_per_query=2.0 * n * TOKENS_PER_QUERY["task"],
            input_bytes=8_192,
        )

    if cfg.has_vision_stub:
        n_enc = max(1, n_total // 10)   # stub frontend + projector share
        enc = ModuleSpec(
            name=f"{cfg.name}-vision-stub", kind="encoder", modality="vision",
            n_params=n_enc, flops_per_query=2.0 * n_enc * TOKENS_PER_QUERY["vision"],
            input_bytes=600_000,
        )
        return ModelSpec(cfg.name, "vqa-dec", (enc,), lm_head(n_total - n_enc))
    if cfg.is_encoder_decoder:
        # real split: encoder tower params vs decoder params
        from repro.layers.initializers import spec_param_count as spc
        from repro.models.encdec import _enc_block_specs

        n_enc = spc(_enc_block_specs(cfg)) * cfg.n_encoder_layers \
            + cfg.d_model * cfg.d_model
        enc = ModuleSpec(
            name=f"{cfg.name}-audio-encoder", kind="encoder", modality="audio",
            n_params=n_enc, flops_per_query=2.0 * n_enc * TOKENS_PER_QUERY["audio"],
            input_bytes=960_000,
        )
        return ModelSpec(cfg.name, "asr", (enc,), lm_head(n_total - n_enc))
    return ModelSpec(cfg.name, "text-gen", (), lm_head(n_total))
