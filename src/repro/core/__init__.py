"""S2M3 core: split-and-share module model, placement, routing.

This package is the paper's contribution:
  module.py    — functional-level modules & model decomposition (§IV-A)
  registry.py  — cross-task module sharing / dedup (§IV-B)
  cluster.py   — device pool + link model (testbed or TPU sub-meshes)
  placement.py — greedy Algorithm 1, brute-force Upper, baselines (§V-B)
  routing.py   — per-request parallel routing + event simulator (§V)
  profiles.py  — the paper's testbed calibration (Tables III/V/VI/VII)
  zoo.py       — the 14-model zoo as ModelSpecs + assigned-arch adapters
  tpu.py       — S2M3 on a TPU pod: sub-mesh devices, roofline t_comp
"""

from repro.core.module import ModelSpec, ModuleSpec  # noqa: F401
from repro.core.registry import ModuleRegistry  # noqa: F401
