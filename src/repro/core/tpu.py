"""S2M3 on a TPU pod: sub-meshes as devices, roofline-derived t_comp.

The pod mesh is partitioned into sub-meshes; each sub-mesh is a
``DeviceSpec`` whose memory is its aggregate HBM and whose compute model
comes from the three-term roofline (common/hw.py) rather than wall-clock
measurement.  The same greedy placement / parallel routing then runs
unchanged — that is the point: the paper's algorithms are
measurement-agnostic.

Module compute estimates use the dry-run's cost-analysis when artifacts
exist (results/dryrun/*.json), falling back to analytic 2·N·tokens.
ICI links between sub-meshes are modeled at the assignment's constant.
"""

from __future__ import annotations

import json
import math
import pathlib
from dataclasses import dataclass

from repro.common.hw import DEFAULT_CHIP, ChipSpec
from repro.core.cluster import ClusterSpec, DeviceSpec
from repro.core.module import ModuleSpec

ARTIFACT_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


@dataclass(frozen=True)
class SubMesh:
    name: str
    n_chips: int
    chip: ChipSpec = DEFAULT_CHIP

    @property
    def hbm_bytes(self) -> int:
        return int(self.n_chips * self.chip.hbm_bytes)

    @property
    def flops(self) -> float:
        return self.n_chips * self.chip.peak_flops_bf16


def pod_cluster(
    partitions: list[int],
    *,
    chip: ChipSpec = DEFAULT_CHIP,
    mfu: float = 0.4,
) -> ClusterSpec:
    """Partition a pod into sub-meshes, e.g. [64, 64, 64, 64] for a 256-chip
    pod split four ways.  ``mfu`` discounts peak FLOP/s to a realistic
    serving efficiency for the fallback compute model."""
    devices = []
    links = {}
    for i, n in enumerate(partitions):
        sm = SubMesh(f"submesh{i}x{n}", n, chip)
        devices.append(DeviceSpec(
            name=sm.name, mem_capacity=sm.hbm_bytes,
            compute_speed=sm.flops * mfu, kind="submesh"))
    # ICI between adjacent sub-meshes: boundary links of the torus slice.
    for i in range(len(partitions)):
        for j in range(i + 1, len(partitions)):
            boundary = int(math.sqrt(min(partitions[i], partitions[j])))
            bw = boundary * chip.ici_bandwidth
            a, b = devices[i].name, devices[j].name
            links[(a, b)] = (bw, 1e-5)
    return ClusterSpec(devices=devices, links=links,
                       default_bandwidth=chip.ici_bandwidth,
                       default_latency=1e-5)


def roofline_t_comp(module: ModuleSpec, n_chips: int,
                    chip: ChipSpec = DEFAULT_CHIP) -> float:
    """max(compute, memory) term for one query on an n-chip sub-mesh."""
    flops = module.flops_per_query
    byts = module.mem_bytes          # weights stream once per query (bs=1)
    t_comp = flops / (n_chips * chip.peak_flops_bf16)
    t_mem = byts / (n_chips * chip.hbm_bandwidth)
    return max(t_comp, t_mem)


def install_roofline_profile(cluster: ClusterSpec, modules,
                             chip: ChipSpec = DEFAULT_CHIP) -> ClusterSpec:
    chips_of = {d.name: int(d.name.rsplit("x", 1)[1]) for d in cluster.devices}
    for m in modules:
        for d in cluster.devices:
            cluster.comp_table[(m.name, d.name)] = roofline_t_comp(
                m, chips_of[d.name], chip)
    return cluster


def load_dryrun_t_comp(arch: str, shape: str, mesh: str = "pod16x16"):
    """Roofline seconds from a dry-run artifact, if present."""
    f = ARTIFACT_DIR / f"{arch}__{shape}__{mesh}.json"
    if not f.exists():
        return None
    data = json.loads(f.read_text())
    return data.get("roofline", {}).get("roofline_s")
