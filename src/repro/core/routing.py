"""Per-request parallel routing + event-driven latency simulator (§V).

Faithful to Eq. (1)–(3): a request's encoders run in parallel on their
chosen devices; encoder latency is the max over modalities of
(input comm + compute + output comm to the head device); the head runs
after all encoder outputs arrive.  Routing follows Eq. (7): each module
goes to the *hosting* device with minimal compute time ("paper" policy).
The "queue-aware" policy (beyond-paper) picks the device minimizing
predicted completion including queueing — used as an optimized variant
in benchmarks.

Modeling choices that mirror the testbed:
* devices execute one module call at a time (capacity a_{m,n} = serial);
* input sends serialize on the requester's uplink, and the paper's
  longest-encoder-first dispatch order is applied;
* pipelining: the next request may start as soon as modules free up;
* optional module-level batching (§VI-C): requests for the same module
  merge into one call with t(k) = t(1) * (0.684 + 0.316 k), the linear
  fit of the paper's footnote-4 measurements (1.28s/4.90s/9.16s for
  batch 1/10/20).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

from repro.core.cluster import ClusterSpec
from repro.core.module import ModelSpec
from repro.core.placement import Placement

BATCH_A, BATCH_B = 0.684, 0.316


def batch_factor(k: int) -> float:
    return BATCH_A + BATCH_B * k if k > 1 else 1.0


@dataclass(frozen=True)
class Request:
    """Unified request: drives both the latency simulator and the live
    engine (s2m3.Deployment.simulate / .submit).  The sim reads the
    scheduling fields; the engine additionally consumes ``inputs`` /
    ``head_extra`` payloads, which are excluded from equality."""

    rid: int
    model: str
    source: str
    arrival: float = 0.0
    batch: int = 1
    # per-modality work multiplicity, e.g. {"text": 100} for a retrieval
    # request carrying 100 candidate prompts (see core.profiles)
    work: tuple[tuple[str, float], ...] = ()
    # live-execution payloads: modality -> array, and head kwargs
    inputs: Any = field(default=None, compare=False, repr=False)
    head_extra: Any = field(default=None, compare=False, repr=False)
    # generative requests (models whose head is ModuleSpec.generative):
    # prompt token ids plus decode controls.  The scheduler streams such
    # requests through the paged-KV decode substrate.
    prompt: tuple[int, ...] | None = None
    max_new_tokens: int = 16
    temperature: float = 0.0      # <= 0: greedy (deterministic)
    eos_id: int = -1              # -1: never stop early
    slo_deadline: float | None = None   # seconds from admit; orders admission

    def work_of(self, modality: str) -> float:
        for k, v in self.work:
            if k == modality:
                return v
        return 1.0


def work_multiplier(req: "Request", modality: str, device) -> float:
    """1 + (work-1)*rho: device-dependent marginal cost of extra queries."""
    w = req.work_of(modality)
    rho = getattr(device, "extra_work_factor", 1.0)
    return 1.0 + (w - 1.0) * rho


@dataclass(frozen=True)
class QueueSnapshot:
    """Live queue state, shared language between the serving scheduler
    and the routing policies.  ``device_free`` is the same device ->
    predicted-busy-until mapping the event simulator threads through
    ``RouteQuery.device_free`` — but observed from a *real* scheduler,
    so ``queue_aware`` routing ranks replica hosts by actual load
    instead of the engine's always-empty deploy-time queue.  ``depths``
    adds per-module queued-stage counts for stats/backpressure
    introspection."""

    t: float                                  # observation time (s, scheduler epoch)
    device_free: tuple[tuple[str, float], ...] = ()
    depths: tuple[tuple[str, int], ...] = ()

    def free_map(self) -> dict[str, float]:
        return dict(self.device_free)

    def depth_of(self, module: str) -> int:
        return dict(self.depths).get(module, 0)


@dataclass(frozen=True)
class Event:
    rid: int
    module: str
    device: str
    kind: str       # comm_in | comp | comm_out | head_comp
    start: float
    end: float


@dataclass
class SimResult:
    latencies: dict[int, float] = field(default_factory=dict)
    events: list[Event] = field(default_factory=list)
    feasible: bool = True

    @property
    def total_latency(self) -> float:
        if not self.feasible:
            return float("inf")
        return sum(self.latencies.values())

    @property
    def mean_latency(self) -> float:
        if not self.feasible or not self.latencies:
            return float("inf")
        return self.total_latency / len(self.latencies)

    @property
    def max_latency(self) -> float:
        if not self.feasible:
            return float("inf")
        # a feasible empty workload has no latency, not an infinite one
        return max(self.latencies.values(), default=0.0)


def _pick_device(module, hosts, cluster, device_free, ready_time,
                 policy: str, source: str, req: "Request"):
    if not hosts:
        return None
    # routing policies are named, registered callables (s2m3.policies);
    # imported lazily so core stays importable on its own
    from repro.s2m3.policies import RouteQuery, get_routing

    return get_routing(policy)(RouteQuery(
        module=module, hosts=tuple(hosts), cluster=cluster, source=source,
        request=req, ready_time=ready_time, device_free=device_free))


def simulate(
    requests: list[Request],
    placement: Placement,
    cluster: ClusterSpec,
    models: list[ModelSpec],
    *,
    policy: str = "paper",
    pipeline: bool = True,
    straggler_threshold: float = 0.0,   # >0: skip devices with EWMA > k*median
) -> SimResult:
    by_name = {m.name: m for m in models}
    device_free: dict[str, float] = {}
    uplink_free: dict[str, float] = {}
    res = SimResult()
    serial_clock = 0.0   # without pipelining, requests strictly serialize

    for q in sorted(requests, key=lambda r: (r.arrival, r.rid)):
        mdl = by_name[q.model]
        start0 = q.arrival if pipeline else max(q.arrival, serial_clock)

        # --- choose devices (Eq. 7) ---
        chosen: dict[str, str] = {}
        for m in mdl.modules:
            hosts = list(placement.devices_for(m.name))
            if straggler_threshold > 0 and len(hosts) > 1:
                import statistics

                med = statistics.median(device_free.get(h, 0.0) for h in hosts)
                hosts = [h for h in hosts
                         if device_free.get(h, 0.0) <= straggler_threshold * med
                         or device_free.get(h, 0.0) == 0.0] or hosts
            dev = _pick_device(m, hosts, cluster, device_free, start0,
                               policy, q.source, q)
            if dev is None:
                res.feasible = False
                return res
            chosen[m.name] = dev

        head_dev = chosen[mdl.head.name]

        # --- encoders in parallel; source uplink serializes sends,
        #     longest-encoding modality dispatched first ---
        enc_order = sorted(
            mdl.encoders,
            key=lambda m: -cluster.t_comp(m, cluster.device(chosen[m.name]))
            * work_multiplier(q, m.modality, cluster.device(chosen[m.name])),
        )
        enc_out_arrival = []
        up_free = max(uplink_free.get(q.source, 0.0), start0)
        for m in enc_order:
            dname = chosen[m.name]
            dev = cluster.device(dname)
            t_in = cluster.t_comm(q.source, dname, m.input_bytes * q.batch)
            send_start = up_free
            send_end = send_start + t_in
            up_free = send_end if dname != q.source else send_start
            comp_start = max(send_end, device_free.get(dname, 0.0))
            t_comp = cluster.t_comp(m, dev) * batch_factor(q.batch) \
                * work_multiplier(q, m.modality, dev)
            comp_end = comp_start + t_comp
            device_free[dname] = comp_end
            t_out = cluster.t_comm(dname, head_dev, m.output_bytes * q.batch)
            enc_out_arrival.append(comp_end + t_out)
            res.events += [
                Event(q.rid, m.name, dname, "comm_in", send_start, send_end),
                Event(q.rid, m.name, dname, "comp", comp_start, comp_end),
                Event(q.rid, m.name, head_dev, "comm_out", comp_end,
                      comp_end + t_out),
            ]
        uplink_free[q.source] = up_free

        # head-only models: the source ships the raw input to the head;
        # the send contends on the same uplink the encoder sends use
        if not mdl.encoders:
            t_in = cluster.t_comm(q.source, head_dev,
                                  mdl.head.input_bytes * q.batch)
            send_start = up_free
            send_end = send_start + t_in
            up_free = send_end if head_dev != q.source else send_start
            uplink_free[q.source] = up_free
            enc_out_arrival.append(send_end)
            res.events.append(
                Event(q.rid, mdl.head.name, head_dev, "comm_in",
                      send_start, send_end))

        # --- task head (Eq. 3) ---
        ready = max(enc_out_arrival) if enc_out_arrival else start0
        h_start = max(ready, device_free.get(head_dev, 0.0))
        t_head = cluster.t_comp(mdl.head, cluster.device(head_dev)) \
            * batch_factor(q.batch)
        h_end = h_start + t_head
        device_free[head_dev] = h_end
        res.events.append(
            Event(q.rid, mdl.head.name, head_dev, "head_comp", h_start, h_end))

        res.latencies[q.rid] = h_end - start0
        serial_clock = h_end
    return res


def _merge_work(a: tuple[tuple[str, float], ...],
                b: tuple[tuple[str, float], ...]) -> tuple[tuple[str, float], ...]:
    """Merged request keeps the worst-case per-modality multiplicity: the
    batched module call must still run every candidate prompt."""
    acc = dict(a)
    for k, v in b:
        acc[k] = max(acc.get(k, 1.0), v)
    return tuple(sorted(acc.items()))


def coalesce_batches(requests: list[Request], window: float = 0.0
                     ) -> list[Request]:
    """Module-level batching (§VI-C): merge same-model requests whose
    arrivals fall within `window` into one batched request.

    Requests carrying live-execution payloads (``inputs`` /
    ``head_extra``) are never merged: a merged Request keeps only one
    payload, so coalescing them would silently drop the others' data
    when the result is fed to ``submit()``.  Payload batching is the
    serving scheduler's job (serving.scheduler), which stacks the
    arrays instead of discarding them.
    """
    out: list[Request] = []
    pend: dict[str, Request] = {}
    for q in sorted(requests, key=lambda r: r.arrival):
        if q.inputs is not None or q.head_extra is not None:
            out.append(q)                     # payload-carrying: never merge
            continue
        cur = pend.get(q.model)
        if cur is not None and q.arrival - cur.arrival <= window:
            pend[q.model] = replace(cur, batch=cur.batch + q.batch,
                                    work=_merge_work(cur.work, q.work))
        else:
            if cur is not None:
                out.append(cur)
            pend[q.model] = q
    out.extend(pend.values())
    return sorted(out, key=lambda r: (r.arrival, r.rid))


def timeline_ascii(result: SimResult, width: int = 72) -> str:
    """Fig.-3-style ASCII timeline of the event trace."""
    if not result.events:
        return "(no events)"
    t1 = max(e.end for e in result.events) or 1.0
    rows = []
    for e in result.events:
        a = int(e.start / t1 * width)
        b = max(a + 1, int(e.end / t1 * width))
        bar = " " * a + {"comm_in": "~", "comp": "#", "comm_out": ">",
                         "head_comp": "H"}[e.kind] * (b - a)
        rows.append(f"r{e.rid:<3}{e.module[:18]:<19}{e.device[:8]:<9}|{bar}")
    return "\n".join(rows)
