"""Paper-testbed calibration (Tables III, V, VI, VII).

This container has no edge devices, so ``t_comp`` is a calibrated model:
per-module FLOPs (2·N·tokens) divided by per-device *effective* speeds,
fitted to the paper's own end-to-end anchors:

  anchor (paper)                               value   source
  ------------------------------------------  ------  ---------
  CLIP ViT-B/16 centralized on server (GPU)    2.44 s  Table VII
  ... on desktop                               3.46 s  Table VII
  ... on laptop                                3.02 s  Table VII
  ... on server w/o GPU                        6.70 s  Table VII
  ... on Jetson Nano                          45.19 s  Table VII
  LLaVA-class head on server                  ~1.5 s   Table XI

Effective speeds fold in the unoptimized single-image PyTorch pipeline
the paper measures (they are far below peak FLOP/s — intentionally).
LLM heads get a kind-multiplier because autoregressive serving stacks
are much better optimized per FLOP than single-image vision pipelines.
Memory numbers are exact (param counts are published); latency
reproduces the paper's *trends* and is reported with deltas in
EXPERIMENTS.md.
"""

from __future__ import annotations

from repro.core.cluster import ClusterSpec, DeviceSpec

GB = 1024**3

# tokens per query by modality — drives flops_per_query = 2 * N * tokens
TOKENS_PER_QUERY = {
    "vision": 197,     # ViT-B/16 grid + CLS
    "text": 20,
    "audio": 500,
    "task": 30,        # LLM head: generated tokens per answer
}

# per-module-kind speed multiplier (serving-stack efficiency).  Text
# encoders run short sequences (overhead-bound: 1/3 the per-FLOP rate);
# LLM heads generate ~30 tokens through heavily-optimized decoder stacks
# (~3x the single-image vision pipeline's per-FLOP rate).
KIND_SPEED = {
    "vision": 1.0,
    "text": 0.33,
    "audio": 1.0,
    "task": 3.0,
}

# Retrieval requests carry ~100 candidate class prompts (zero-shot
# classification over the benchmark label set) — this is why the paper's
# text encoder dominates retrieval latency (footnote 2: 3 s laptop / 43 s
# Jetson) while encoder-only VQA with ONE question is 10x faster on the
# same modules (Table VI).  The multiplicity lives on the REQUEST
# (core.routing.Request.work), not the module — shared modules keep one
# signature.  Per-device marginal cost of the extra prompts is
# DeviceSpec.extra_work_factor (rho): batched backends amortize
# (rho=0.24); the 4 GB Jetson is super-linear (rho=1.47, memory thrash).
RETRIEVAL_TEXT_QUERIES = 100

# (speed, rho) jointly fitted to THREE anchor families:
#   retrieval centralized per device (Table VII: 2.44/6.70/3.46/3.02/45.19),
#   encoder-only VQA-S (Table VI: server 1.23, jetson 6.28),
#   the parallel-processing saving (Table VII: 3.03-2.48 = 0.55 s =
#   ViT-B/16 vision time on the desktop).
# Resulting closed-form predictions: S2M3 2.45 (paper 2.48), no-parallel
# 2.99 (3.03), VQA-S S2M3 0.62 (0.50) — see EXPERIMENTS.md.
EFFECTIVE_SPEED = {
    "server": 31.4e9,
    "server-nogpu": 11.4e9,
    "desktop": 61.8e9,
    "laptop": 54.8e9,
    "jetson-a": 6.15e9,
    "jetson-b": 6.15e9,
}

EXTRA_WORK_FACTOR = {
    "server": 0.083,
    "server-nogpu": 0.083,
    "desktop": 0.384,
    "laptop": 0.278,
    "jetson-a": 0.525,
    "jetson-b": 0.525,
}

# memory available for fp32 module weights (Table III).  The Jetson's
# effective budget is fitted to the paper's own feasibility boundary
# (Table VI '—' rows): CLIP RN50x4 (584 MB fp32) runs, RN50x16 (1.01 GB)
# does not — the 4 GB board keeps ~3 GB for OS + runtime + activations.
MEM_CAPACITY = {
    "server": int(23.9 * GB),
    "server-nogpu": int(33.7 * GB),
    "desktop": int(28.0 * GB),
    "laptop": int(14.0 * GB),
    "jetson-a": int(0.8 * GB),
    "jetson-b": int(0.8 * GB),
}

# model load+download time per GB (footnote 1: CLIP ViT-B/16 ≈ 20.44 s
# for 0.6 GB of fp32 weights -> ~34 s/GB on the testbed)
LOAD_SECONDS_PER_GB = 34.0


def make_testbed(*, with_server: bool = False, server_gpu: bool = True
                 ) -> ClusterSpec:
    """The paper's 4-device PAN (+ optional MAN server)."""
    def _dev(name, kind="edge"):
        return DeviceSpec(name, MEM_CAPACITY[name], EFFECTIVE_SPEED[name],
                          kind=kind,
                          extra_work_factor=EXTRA_WORK_FACTOR[name])

    devices = [_dev("desktop"), _dev("laptop"), _dev("jetson-a"),
               _dev("jetson-b")]
    links = {}
    if with_server:
        name = "server" if server_gpu else "server-nogpu"
        devices.append(_dev(name, kind="server"))
        for d in ("desktop", "laptop", "jetson-a", "jetson-b"):
            # MAN link: dedicated server, 4-5 ms per packet (paper §VI)
            links[(d, name)] = (25e6, 0.0045)
    return ClusterSpec(
        devices=devices,
        links=links,
        default_bandwidth=12.5e6,   # 100 Mbps home Wi-Fi/wired mix
        default_latency=0.005,
    )


def effective_t_comp(module, device: DeviceSpec) -> float:
    mult = KIND_SPEED.get(module.modality, 1.0)
    if module.flops_per_query <= 0:
        return 1e-4
    return module.flops_per_query / (device.compute_speed * mult)


def install_profile(cluster: ClusterSpec, modules) -> ClusterSpec:
    """Precompute the (module, device) comp table with kind multipliers."""
    for m in modules:
        for d in cluster.devices:
            cluster.comp_table[(m.name, d.name)] = effective_t_comp(m, d)
    return cluster


def load_time(module, device: DeviceSpec) -> float:
    """End-to-end adds module download+load (footnote 1)."""
    return module.mem_bytes / GB * LOAD_SECONDS_PER_GB
