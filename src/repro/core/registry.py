"""Module sharing registry (paper §IV-B).

Tracks which modules are deployed and which models reference them; adding
a task only materializes modules not already present.  Total cost drops
from O(|M|·r) (dedicated copies) to O(c·r) with c distinct modules.

At TPU scale the same registry keys the HBM parameter store
(serving/engine.py): one buffer per signature, many models.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.module import ModelSpec, ModuleSpec


@dataclass
class _Entry:
    module: ModuleSpec
    refs: set[str] = field(default_factory=set)


class ModuleRegistry:
    def __init__(self):
        self._entries: dict[str, _Entry] = {}
        self._models: dict[str, ModelSpec] = {}

    # -- mutation -----------------------------------------------------------
    def add_model(self, model: ModelSpec) -> list[ModuleSpec]:
        """Register a model; returns the modules that are newly required."""
        if model.name in self._models:
            return []
        self._models[model.name] = model
        new = []
        for m in model.modules:
            e = self._entries.get(m.name)
            if e is None:
                e = self._entries[m.name] = _Entry(m)
                new.append(m)
            elif e.module != m:
                raise ValueError(f"signature collision on {m.name}")
            e.refs.add(model.name)
        return new

    def remove_model(self, name: str) -> list[ModuleSpec]:
        """Deregister; returns modules that became garbage (refcount 0)."""
        model = self._models.pop(name, None)
        if model is None:
            return []
        freed = []
        for m in model.modules:
            e = self._entries[m.name]
            e.refs.discard(name)
            if not e.refs:
                freed.append(m)
                del self._entries[m.name]
        return freed

    # -- queries ------------------------------------------------------------
    @property
    def models(self) -> dict[str, ModelSpec]:
        return dict(self._models)

    @property
    def modules(self) -> dict[str, ModuleSpec]:
        return {k: e.module for k, e in self._entries.items()}

    def refcount(self, module_name: str) -> int:
        e = self._entries.get(module_name)
        return len(e.refs) if e else 0

    def shared_bytes(self) -> int:
        """Deployment cost WITH sharing: one copy per distinct module."""
        return sum(e.module.mem_bytes for e in self._entries.values())

    def dedicated_bytes(self) -> int:
        """Deployment cost WITHOUT sharing: a copy per (model, module)."""
        return sum(m.total_bytes for m in self._models.values())

    def sharing_savings(self) -> float:
        d = self.dedicated_bytes()
        return 0.0 if d == 0 else 1.0 - self.shared_bytes() / d
