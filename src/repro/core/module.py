"""Functional-level modules and model decomposition (paper §IV-A).

A multi-modal model M_k = M_k^enc ∪ {h_k}: a set of modality-wise
encoder modules plus one task head.  ``ModuleSpec.name`` is the sharing
signature: two models containing a module with the same name share one
deployment (same architecture AND parameters — paper Insight 4).
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModuleSpec:
    name: str                     # sharing signature
    kind: str                     # "encoder" | "head"
    modality: str                 # vision | text | audio | task
    n_params: int
    bytes_per_param: float = 2.0  # fp16 deployment
    flops_per_query: float = 0.0  # fallback compute model: flops/speed
    input_bytes: int = 600_000    # request payload routed to this module
    output_bytes: int = 4_096     # embedding forwarded to the head
    # generative (decoder) heads: requests stream tokens through the
    # paged-KV decode substrate instead of a single head call
    generative: bool = False
    # per-token KV-cache footprint summed over layers (bytes); feeds the
    # plan_check page-budget ledger for generative heads
    kv_bytes_per_token: int = 0

    @property
    def mem_bytes(self) -> int:
        return int(self.n_params * self.bytes_per_param)

    def __str__(self) -> str:
        return f"{self.name}[{self.kind}/{self.modality}]"


@dataclass(frozen=True)
class ModelSpec:
    name: str
    task: str
    encoders: tuple[ModuleSpec, ...]
    head: ModuleSpec

    @property
    def modules(self) -> tuple[ModuleSpec, ...]:
        return (*self.encoders, self.head)

    @property
    def n_params(self) -> int:
        return sum(m.n_params for m in self.modules)

    @property
    def max_module_bytes(self) -> int:
        """Worst single-device deployment cost under the split architecture."""
        return max(m.mem_bytes for m in self.modules)

    @property
    def total_bytes(self) -> int:
        """Deployment cost without splitting (centralized)."""
        return sum(m.mem_bytes for m in self.modules)

    @property
    def parallel_degree(self) -> int:
        """Number of encoders that can run concurrently (Insight 2)."""
        return len(self.encoders)


def distinct_modules(models) -> dict[str, ModuleSpec]:
    """The entire module set M = ∪_k M_k, deduplicated by signature."""
    out: dict[str, ModuleSpec] = {}
    for mdl in models:
        for m in mdl.modules:
            prev = out.setdefault(m.name, m)
            if prev != m:
                raise ValueError(
                    f"signature collision: {m.name} declared with different specs")
    return out
