"""Device pool and link model.

A ``DeviceSpec`` is anything that can host modules: an edge device from
the paper's testbed (Table III) or a TPU sub-mesh (core/tpu.py).
``t_comp(module, device)`` resolution order: explicit measured table
(paper calibration) -> flops/effective-speed fallback.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.module import ModuleSpec


@dataclass(frozen=True)
class DeviceSpec:
    name: str
    mem_capacity: int            # bytes available for module weights
    compute_speed: float         # effective FLOP/s for the fallback model
    kind: str = "edge"           # edge | server | submesh
    # marginal cost of additional same-module queries relative to the
    # first (batched backends amortize: rho < 1; a thrashing 4 GB Jetson
    # is super-linear: rho > 1).  Routing applies
    # t = t_comp * (1 + (work - 1) * rho).
    extra_work_factor: float = 1.0


@dataclass
class ClusterSpec:
    devices: list[DeviceSpec]
    # (src_name, dst_name) -> (bandwidth bytes/s, latency s); missing ->
    # default link.  src == dst -> zero-cost.
    links: dict[tuple[str, str], tuple[float, float]] = field(default_factory=dict)
    default_bandwidth: float = 12.5e6      # 100 Mbps home network
    default_latency: float = 0.005
    # measured per-(module, device) compute seconds (paper calibration)
    comp_table: dict[tuple[str, str], float] = field(default_factory=dict)

    def device(self, name: str) -> DeviceSpec:
        for d in self.devices:
            if d.name == name:
                return d
        raise KeyError(name)

    def t_comm(self, src: str, dst: str, nbytes: float) -> float:
        if src == dst:
            return 0.0
        bw, lat = self.links.get(
            (src, dst), self.links.get((dst, src),
                                       (self.default_bandwidth,
                                        self.default_latency)))
        return lat + nbytes / bw

    def t_comp(self, module: ModuleSpec, device: DeviceSpec) -> float:
        key = (module.name, device.name)
        if key in self.comp_table:
            return self.comp_table[key]
        if module.flops_per_query <= 0:
            # parameter-free heads (cosine similarity / InfoNCE): negligible
            return 1e-4
        return module.flops_per_query / device.compute_speed

    def without(self, *names: str) -> "ClusterSpec":
        """Cluster with devices removed (availability scenarios, Table IX)."""
        keep = [d for d in self.devices if d.name not in names]
        return ClusterSpec(
            devices=keep, links=self.links,
            default_bandwidth=self.default_bandwidth,
            default_latency=self.default_latency, comp_table=self.comp_table,
        )

    def with_device(self, dev: DeviceSpec) -> "ClusterSpec":
        return ClusterSpec(
            devices=[*self.devices, dev], links=self.links,
            default_bandwidth=self.default_bandwidth,
            default_latency=self.default_latency, comp_table=self.comp_table,
        )
