"""Module placement (paper §V-B, Algorithm 1 lines 1–13) + baselines.

``greedy_place`` is the paper's algorithm: modules in descending memory
order; encoders to the device minimizing *completion time* (Eq. 5 —
compute time plus accumulated compute of modules already on the device),
heads to the device minimizing pure compute time (Eq. 6); first fit that
satisfies the memory constraint (Eq. 4d).  An optional replication pass
fills leftover memory with copies of the largest modules (paper: "If we
have remaining resources, we replicate the modules with larger memory
requirements").

``optimal_place`` is the paper's *Upper* baseline: brute-force
enumeration minimizing simulated total latency — exact but exponential;
only for small instances (the paper's testbed is 5 devices × ≤4 modules).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field

from repro.core.cluster import ClusterSpec, DeviceSpec
from repro.core.module import ModelSpec, ModuleSpec, distinct_modules


@dataclass
class Placement:
    # module signature -> list of device names hosting a replica
    assignment: dict[str, list[str]] = field(default_factory=dict)
    feasible: bool = True
    infeasible_modules: list[str] = field(default_factory=list)
    # per-module deployed bytes, keyed like ``assignment`` (filled by the
    # placement strategies; lets reports compute per-device ledgers even
    # for no-share placements whose keys are model-suffixed)
    module_bytes: dict[str, int] = field(default_factory=dict)

    def devices_for(self, module_name: str) -> list[str]:
        return self.assignment.get(module_name, [])

    def modules_on(self, device_name: str) -> list[str]:
        return [m for m, devs in self.assignment.items() if device_name in devs]

    def bytes_on(self, device_name: str, modules: dict[str, ModuleSpec]) -> int:
        return sum(modules[m].mem_bytes for m in self.modules_on(device_name))

    def bytes_used_on(self, device_name: str,
                      module_bytes: dict[str, int] | None = None) -> int:
        """Ledger bytes a device carries, from a bytes-per-key map
        (defaults to ``self.module_bytes``; unknown keys count 0)."""
        mb = module_bytes if module_bytes is not None else self.module_bytes
        return sum(mb.get(m, 0) for m in self.modules_on(device_name))

    def ledger(self, devices,
               module_bytes: dict[str, int] | None = None
               ) -> dict[str, dict[str, int]]:
        """Per-device used/capacity/free memory ledger — the single
        source of truth behind ``PlanReport.memory`` and the static
        ``repro.analysis`` plan verifier."""
        out = {}
        for dev in devices:
            used = self.bytes_used_on(dev.name, module_bytes)
            out[dev.name] = {"used": used, "capacity": dev.mem_capacity,
                             "free": dev.mem_capacity - used}
        return out

    def max_device_bytes(self, modules: dict[str, ModuleSpec]) -> int:
        devs = {d for lst in self.assignment.values() for d in lst}
        if not devs:
            return 0
        return max(self.bytes_on(d, modules) for d in devs)


def expected_work(models: list[ModelSpec]) -> dict[str, float]:
    """Per-module expected request-work multiplicity (the paper's
    *measured* t_comp folds the task workload in — e.g. the retrieval
    text encoder runs ~100 candidate prompts per request, footnote 2)."""
    from repro.core.zoo import TASK_WORK

    acc: dict[str, list[float]] = {}
    for mdl in models:
        work = dict(TASK_WORK.get(mdl.task, ()))
        for m in mdl.encoders:
            acc.setdefault(m.name, []).append(work.get(m.modality, 1.0))
        acc.setdefault(mdl.head.name, []).append(1.0)
    return {k: sum(v) / len(v) for k, v in acc.items()}


def _work_adjusted(module: ModuleSpec, dev: DeviceSpec, cluster: ClusterSpec,
                   work: dict[str, float]) -> float:
    w = work.get(module.name, 1.0)
    rho = getattr(dev, "extra_work_factor", 1.0)
    return cluster.t_comp(module, dev) * (1.0 + (w - 1.0) * rho)


def _completion_time(module: ModuleSpec, dev: DeviceSpec, cluster: ClusterSpec,
                     placed: dict[str, list[ModuleSpec]],
                     work: dict[str, float]) -> float:
    """Eq. 5 (encoders) / Eq. 6 (heads), with workload-inclusive times."""
    t = _work_adjusted(module, dev, cluster, work)
    if module.kind == "encoder":
        t += sum(_work_adjusted(m, dev, cluster, work)
                 for m in placed.get(dev.name, []))
    return t


def greedy_place(
    models: list[ModelSpec],
    cluster: ClusterSpec,
    *,
    share: bool = True,
    replicate: bool = False,
) -> Placement:
    """Algorithm 1 (placement half).

    share=False deploys a dedicated copy of every module per model (the
    paper's non-sharing ablation, Table X): signatures are suffixed with
    the model name so nothing dedups.
    """
    work = expected_work(models)
    if share:
        modules = distinct_modules(models)
    else:
        modules = {}
        for mdl in models:
            for m in mdl.modules:
                import dataclasses as _dc

                key = f"{m.name}::{mdl.name}"
                modules[key] = _dc.replace(m, name=key)

    remaining = {d.name: d.mem_capacity for d in cluster.devices}
    placed: dict[str, list[ModuleSpec]] = {}
    out = Placement(module_bytes={k: m.mem_bytes for k, m in modules.items()})

    # line 3: descending memory requirement
    order = sorted(modules.values(), key=lambda m: -m.mem_bytes)
    for m in order:
        # line 4: devices ascending by completion time
        ranked = sorted(
            cluster.devices,
            key=lambda d: _completion_time(m, d, cluster, placed, work),
        )
        for dev in ranked:                      # lines 5-11: first fit
            if m.mem_bytes <= remaining[dev.name]:
                out.assignment.setdefault(m.name, []).append(dev.name)
                remaining[dev.name] -= m.mem_bytes
                placed.setdefault(dev.name, []).append(m)
                break
        else:
            out.feasible = False
            out.infeasible_modules.append(m.name)

    if replicate:
        # fill leftover memory with replicas of the largest modules
        for m in order:
            for dev in cluster.devices:
                if (dev.name not in out.assignment.get(m.name, ())
                        and m.mem_bytes <= remaining[dev.name]):
                    out.assignment[m.name].append(dev.name)
                    remaining[dev.name] -= m.mem_bytes
                    placed.setdefault(dev.name, []).append(m)
    return out


def centralized_place(models: list[ModelSpec], cluster: ClusterSpec,
                      device_name: str) -> Placement:
    """Everything on one device (the paper's Cloud / Local baselines)."""
    modules = distinct_modules(models)
    dev = cluster.device(device_name)
    total = sum(m.mem_bytes for m in modules.values())
    out = Placement(
        assignment={m: [device_name] for m in modules},
        module_bytes={k: m.mem_bytes for k, m in modules.items()})
    if total > dev.mem_capacity:
        out.feasible = False
        out.infeasible_modules = list(modules)
    return out


def optimal_place(
    models: list[ModelSpec],
    cluster: ClusterSpec,
    workload,                       # list[Request] — evaluated by routing sim
    *,
    max_nodes: int = 8,
) -> tuple[Placement, float]:
    """Brute-force 'Upper' baseline: minimize simulated total latency."""
    from repro.core.routing import simulate

    modules = list(distinct_modules(models).values())
    if len(modules) * len(cluster.devices) > max_nodes * 8:
        # guard: enumeration is |N|^{|M|}
        raise ValueError(
            f"optimal_place would enumerate {len(cluster.devices)}^"
            f"{len(modules)} assignments (modules x devices = "
            f"{len(modules) * len(cluster.devices)} > {max_nodes * 8}); "
            "raise max_nodes or use the greedy strategy")
    best, best_t = None, float("inf")
    names = [d.name for d in cluster.devices]
    caps = {d.name: d.mem_capacity for d in cluster.devices}
    for combo in itertools.product(names, repeat=len(modules)):
        used: dict[str, int] = {}
        ok = True
        for m, dev in zip(modules, combo):
            used[dev] = used.get(dev, 0) + m.mem_bytes
            if used[dev] > caps[dev]:
                ok = False
                break
        if not ok:
            continue
        pl = Placement(
            assignment={m.name: [dev] for m, dev in zip(modules, combo)},
            module_bytes={m.name: m.mem_bytes for m in modules})
        result = simulate(workload, pl, cluster, models)
        if result.total_latency < best_t:
            best, best_t = pl, result.total_latency
    if best is None:
        return Placement(feasible=False), float("inf")
    return best, best_t


def replan(
    models: list[ModelSpec],
    old_cluster: ClusterSpec,
    new_cluster: ClusterSpec,
    old: Placement,
    *,
    place=None,
) -> tuple[Placement, list[tuple[str, str]]]:
    """Elastic reallocation (paper §VI-C "dynamic network conditions").

    Re-runs the placement (``place(models, cluster)``, default greedy) on
    the new device pool and returns (placement, migrations) where
    migrations lists (module, new_device) pairs that require a load —
    modules already resident stay put when the strategy re-chooses their
    device, so the migration set is the switching cost.
    """
    new = (place or greedy_place)(models, new_cluster)
    migrations = []
    for mod, devs in new.assignment.items():
        for d in devs:
            if d not in old.assignment.get(mod, ()):
                migrations.append((mod, d))
    return new, migrations
