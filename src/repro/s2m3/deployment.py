"""``s2m3.Deployment`` — one lifecycle API from model specs to placed,
routed, servable multi-task inference.

    dep = (Deployment(cluster)
           .add_model(spec, builders)
           .plan(placement="greedy", routing="queue_aware", replicate=True)
           .materialize(device_map))

    report = dep.simulate(workload)      # predicted PlanReport
    result = dep.submit(request)         # real compute (same Request!)
    results = dep.serve(workload)        # continuous-batching scheduler
    dep.evict("retrieval")               # refcounted hot-remove
    dep.replan(cluster.without("dev3"))  # migrate live weights

One ``ModuleRegistry`` backs both planning and the live engine, so the
memory ledger, sharing savings, and eviction refcounts are consistent
between ``simulate()`` and ``submit()``.  Placement strategies and
routing policies are looked up by name in ``s2m3.policies``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

from repro.core.cluster import ClusterSpec
from repro.core.module import ModelSpec
from repro.core.placement import Placement
from repro.core.placement import replan as core_replan
from repro.core.registry import ModuleRegistry
from repro.core.routing import Request, SimResult, coalesce_batches, simulate
from repro.s2m3.policies import get_placement, get_routing

_MB = 1024**2


@dataclass
class PlanReport:
    """What a plan (or replan) means: module→device assignments, the
    per-device memory ledger, sharing savings, and — when a workload was
    simulated — predicted latencies and per-request routes."""

    placement: Placement
    routing: str
    feasible: bool
    assignments: dict[str, list[str]]
    memory: dict[str, dict[str, int]]      # device -> used/capacity/free
    shared_bytes: int
    dedicated_bytes: int
    sharing_savings: float
    sim: SimResult | None = None
    routes: dict[int, dict[str, str]] = field(default_factory=dict)
    migrations: list[tuple[str, str]] = field(default_factory=list)

    @property
    def total_latency(self) -> float:
        return self.sim.total_latency if self.sim else float("nan")

    @property
    def mean_latency(self) -> float:
        return self.sim.mean_latency if self.sim else float("nan")

    @property
    def max_latency(self) -> float:
        return self.sim.max_latency if self.sim else float("nan")

    def devices_for(self, module_name: str) -> list[str]:
        return self.assignments.get(module_name, [])

    def summary(self) -> str:
        lines = [f"plan: routing={self.routing} "
                 f"{'feasible' if self.feasible else 'INFEASIBLE'}"]
        for mod, hosts in sorted(self.assignments.items()):
            lines.append(f"  {mod:24s} -> {', '.join(hosts)}")
        for dev, row in self.memory.items():
            if row["used"]:
                lines.append(
                    f"  mem {dev:12s} {row['used'] / _MB:8.1f} / "
                    f"{row['capacity'] / _MB:.1f} MB")
        lines.append(f"  sharing: {self.shared_bytes / _MB:.1f} MB deployed "
                     f"vs {self.dedicated_bytes / _MB:.1f} MB dedicated "
                     f"({self.sharing_savings:.1%} saved)")
        if self.sim is not None:
            lines.append(f"  predicted latency: mean {self.mean_latency:.3f} s"
                         f"  max {self.max_latency:.3f} s"
                         f"  over {len(self.sim.latencies)} request(s)")
        if self.migrations:
            lines.append(f"  migrations: {self.migrations}")
        return "\n".join(lines)


class Deployment:
    """Facade over registry → placement → routing → execution."""

    def __init__(self, cluster: ClusterSpec, *,
                 registry: ModuleRegistry | None = None):
        self.cluster = cluster
        self.registry = registry or ModuleRegistry()
        self.placement: Placement | None = None
        self.engine = None                     # serving.engine.S2M3Engine
        self.scheduler = None                  # serving.scheduler.ServeScheduler
        self._builders: dict[str, Callable] = {}
        self._placement_name = "greedy"
        self._routing_name = "queue_aware"
        self._plan_opts: dict[str, Any] = {}
        self._workload: list[Request] | None = None

    @property
    def models(self) -> list[ModelSpec]:
        return list(self.registry.models.values())

    @property
    def materialized(self) -> bool:
        return self.engine is not None

    # -- admission ------------------------------------------------------
    def add_model(self, spec: ModelSpec,
                  builders: dict[str, Callable] | None = None) -> "Deployment":
        """Admit a model.  Before ``materialize()`` this only registers
        it (plan is marked stale); on a live deployment it replans,
        migrates, and hot-loads the new modules immediately."""
        if builders:
            self._builders.update(builders)
        self.registry.add_model(spec)
        if self.engine is None:
            self.placement = None              # stale: next plan() covers it
        else:
            self.replan(self.cluster)
            self.engine.deploy_model(spec, self._builders, self.placement)
        return self

    def evict(self, model_name: str) -> list[str]:
        """Refcounted removal: returns module names actually freed
        (shared modules survive while any referencing model remains).
        Raises ``PlanError`` while the model has requests in flight on
        the serving scheduler — evicting mid-serve would deregister a
        model whose sequences still hold decode rows and KV pages
        (invariant ``registry/refcount-consistent``); drain first."""
        if self.scheduler is not None and \
                model_name in self.scheduler.inflight_models():
            from repro.analysis.diagnostics import (Diagnostic, PlanError,
                                                    Severity)
            d = Diagnostic(
                Severity.ERROR, "invariant/registry/refcount-consistent",
                f"evict({model_name!r}): model has requests in flight on "
                "the serving scheduler; drain before evicting",
                entity=model_name,
                hint="call scheduler.drain() (or let serve() return) "
                     "before evict()")
            raise PlanError(d.message, diagnostics=[d])
        if self.engine is not None:
            freed = self.engine.evict_model(model_name)
        else:
            freed = [m.name for m in self.registry.remove_model(model_name)]
        if self.placement is not None:
            for key in list(self.placement.assignment):
                if key in freed or key.endswith(f"::{model_name}"):
                    self.placement.assignment.pop(key, None)
                    self.placement.module_bytes.pop(key, None)
        return freed

    # -- planning -------------------------------------------------------
    def plan(self, placement: str = "greedy",
             routing: str = "queue_aware", *,
             workload: list[Request] | None = None,
             **opts: Any) -> "Deployment":
        """Run a named placement strategy and pin the routing policy.
        Extra kwargs (``replicate=True``, ``device=...``, ``max_nodes``)
        flow to the strategy."""
        get_routing(routing)                   # fail fast on a bad name
        fn = get_placement(placement)
        if placement == "no_share" and self.engine is not None:
            raise NotImplementedError(
                "cannot re-plan a live deployment with 'no_share': it is a "
                "simulation-only baseline (see materialize())")
        self._placement_name, self._routing_name = placement, routing
        self._plan_opts, self._workload = dict(opts), workload
        self.placement = fn(self.models, self.cluster,
                            workload=workload, **opts)
        if self.engine is not None:
            self._sync_engine()
        return self

    def _ensure_plan(self) -> Placement:
        if self.placement is None:
            fn = get_placement(self._placement_name)
            self.placement = fn(self.models, self.cluster,
                                workload=self._workload, **self._plan_opts)
        return self.placement

    def _module_bytes(self, key: str) -> int:
        pl = self.placement
        if pl is not None and key in pl.module_bytes:
            return pl.module_bytes[key]
        mod = self.registry.modules.get(key)
        return mod.mem_bytes if mod else 0

    def report(self, *, sim: SimResult | None = None,
               migrations: list[tuple[str, str]] | None = None) -> PlanReport:
        """PlanReport for the current plan (memory ledger + sharing
        savings; latency/routes when a SimResult is attached)."""
        pl = self._ensure_plan()
        memory = pl.ledger(
            self.cluster.devices,
            {m: self._module_bytes(m) for m in pl.assignment})
        routes: dict[int, dict[str, str]] = {}
        if sim is not None:
            for e in sim.events:
                if e.kind in ("comp", "head_comp"):
                    routes.setdefault(e.rid, {})[e.module] = e.device
        return PlanReport(
            placement=pl, routing=self._routing_name,
            feasible=pl.feasible and (sim.feasible if sim else True),
            assignments={m: list(h) for m, h in pl.assignment.items()},
            memory=memory,
            shared_bytes=self.registry.shared_bytes(),
            dedicated_bytes=self.registry.dedicated_bytes(),
            sharing_savings=self.registry.sharing_savings(),
            sim=sim, routes=routes, migrations=migrations or [])

    # -- verification ---------------------------------------------------
    def verify(self, *, kernels: bool = False,
               vmem_budget: int | None = None,
               decode_pages: int | None = None,
               page_size: int | None = None,
               model_check: bool = False,
               mc_budget: float = 10.0) -> list:
        """Static pre-flight: run the ``repro.analysis`` plan verifier
        against the current plan (memory ledgers, mapping completeness,
        acyclicity, reachability, refcounts, sharing legality, and —
        when decode knobs are given — generative heads' paged-KV page
        budgets) and, with ``kernels=True``, the Pallas kernel checker
        over the zoo's shapes.  ``model_check=True`` additionally
        explores a bounded schedule-space model of this deployment's
        serving state machine (``repro.analysis.modelcheck``) under an
        ``mc_budget``-second wall-clock cap, reporting any invariant
        counterexample as an ERROR with its transition script.  Returns
        the ``Diagnostic`` list and raises nothing;
        ``materialize()``/``serve()`` call it and raise ``PlanError``
        when it reports ERRORs."""
        from repro.analysis import verify_deployment

        return verify_deployment(self, kernels=kernels,
                                 vmem_budget=vmem_budget,
                                 decode_pages=decode_pages,
                                 page_size=page_size,
                                 model_check=model_check,
                                 mc_budget=mc_budget)

    def _preflight(self, stage: str, **verify_kwargs) -> None:
        """Gate a device-touching stage on the static verifier: ERROR
        findings raise ``PlanError`` (with the full diagnostic list
        attached), WARNINGs are logged and execution proceeds."""
        import logging

        from repro.analysis.diagnostics import PlanError, errors, warnings

        diags = self.verify(**verify_kwargs)
        log = logging.getLogger("repro.s2m3")
        for d in warnings(diags):
            log.warning("%s pre-flight: %s", stage, d.format())
        errs = errors(diags)
        if errs:
            raise PlanError(
                f"{stage} pre-flight: plan verification failed with "
                f"{len(errs)} error(s):\n"
                + "\n".join(d.format() for d in errs),
                diagnostics=diags)

    # -- prediction -----------------------------------------------------
    def simulate(self, workload: list[Request], *,
                 policy: str | None = None, pipeline: bool = True,
                 coalesce_window: float | None = None,
                 straggler_threshold: float = 0.0) -> PlanReport:
        """Event-driven latency prediction of ``workload`` under the
        current plan; same Request objects that ``submit()`` executes."""
        self._ensure_plan()
        reqs = (coalesce_batches(workload, coalesce_window)
                if coalesce_window is not None else workload)
        sim = simulate(reqs, self.placement, self.cluster, self.models,
                       policy=policy or self._routing_name,
                       pipeline=pipeline,
                       straggler_threshold=straggler_threshold)
        return self.report(sim=sim)

    # -- execution ------------------------------------------------------
    def materialize(self, device_map: dict[str, Any] | None = None
                    ) -> "Deployment":
        """Bring the plan to life on real jax devices.  ``device_map``
        (placement device name -> jax.Device) defaults to round-robin
        over the local devices."""
        from repro.serving.engine import S2M3Engine

        if self._placement_name == "no_share":
            raise NotImplementedError(
                "placement strategy 'no_share' is a simulation-only "
                "baseline: its model-suffixed assignment keys cannot back "
                "the engine's one-runtime-per-signature store")
        if device_map is None:
            import jax

            devs = jax.devices()
            device_map = {d.name: devs[i % len(devs)]
                          for i, d in enumerate(self.cluster.devices)}
        self._ensure_plan()
        self._preflight("materialize")
        self.engine = S2M3Engine(device_map, registry=self.registry,
                                 cluster=self.cluster,
                                 routing=self._routing_name)
        self.engine.placement = self.placement
        for model in self.models:
            missing = [m.name for m in model.modules
                       if m.name not in self._builders]
            if missing:
                raise KeyError(
                    f"materialize: no builders for modules {missing} of "
                    f"model {model.name!r}; pass builders to add_model()")
            self.engine.deploy_model(model, self._builders, self.placement)
        return self

    def _require_engine(self):
        if self.engine is None:
            raise RuntimeError(
                "deployment not materialized — call .materialize() first "
                "(simulate() works without it)")
        return self.engine

    def submit(self, request: Request):
        """Execute a Request for real: the engine runs the same model the
        simulator predicted, consuming ``request.inputs``.  Generative
        models (head is ``ModuleSpec.generative``) run the solo
        prefill+decode loop and return their token ids as ``output``."""
        model = self.registry.models[request.model]
        if model.head.generative:
            return self._require_engine().generate(request)
        if request.inputs is None:
            raise ValueError(
                f"request {request.rid} has no inputs payload; submit() "
                "needs Request(inputs={modality: array})")
        return self._require_engine().infer(
            request.model, request.inputs,
            head_extra=request.head_extra, rid=request.rid)

    def infer(self, model_name: str, inputs: dict[str, Any],
              head_extra: dict | None = None):
        return self._require_engine().infer(model_name, inputs, head_extra)

    def serve(self, workload: list[Request], *,
              max_batch: int = 8, max_queue_depth: int = 32,
              admission: str = "block", decode_rows: int = 4,
              decode_pages: int = 64, page_size: int = 16,
              max_seq_len: int = 256, on_finish: Callable | None = None,
              config: Any = None):
        """Drain ``workload`` through the continuous-batching scheduler:
        per-module queues, admission control, and cross-task batch
        coalescing at shared encoders (one encoder launch can serve
        requests from several tasks).  Generative requests (models whose
        head is ``ModuleSpec.generative``) stream through the paged-KV
        decode substrate: admission against a page pool of
        ``decode_pages`` pages of ``page_size`` tokens, up to
        ``decode_rows`` sequences decoding per batched launch;
        ``on_finish`` (if given) is called with each ``InferenceResult``
        as its sequence finishes, i.e. out of admission order.  Returns
        one ``InferenceResult`` per request, in workload order;
        ``self.scheduler`` keeps the queue/batch-occupancy and
        page-occupancy stats of the run (``stats_dict()``), directly
        comparable with ``simulate(coalesce_window=...)``."""
        from repro.serving.scheduler import SchedulerConfig, ServeScheduler

        eng = self._require_engine()
        cfg = config or SchedulerConfig(
            max_batch=max_batch, max_queue_depth=max_queue_depth,
            admission=admission, decode_rows=decode_rows,
            decode_pages=decode_pages, page_size=page_size,
            max_seq_len=max_seq_len)
        self._preflight("serve", decode_pages=cfg.decode_pages,
                        page_size=cfg.page_size)
        self.scheduler = ServeScheduler(eng, config=cfg, on_finish=on_finish)
        return self.scheduler.serve(workload)

    # -- observability --------------------------------------------------
    def trace(self):
        """The ``obs.trace.Trace`` of the last ``serve()`` run (falling
        back to the engine's solo-path tracer): per-request span trees,
        exportable via ``Trace.save()`` as Chrome-trace JSON."""
        if self.scheduler is not None:
            return self.scheduler.tracer.trace
        return self._require_engine().tracer.trace

    def compare(self, workload: list[Request], **serve_kwargs):
        """Drift check: run ``simulate()`` and ``serve()`` on the *same*
        requests and reconcile them — route divergences (simulated
        device != measured device, the plan-level invariant), per-module
        predicted-vs-measured latency ratios, and queue-model error.
        Returns an ``obs.drift.DriftReport``."""
        from repro.obs.drift import compare_deployment

        return compare_deployment(self, workload, **serve_kwargs)

    # -- elasticity -----------------------------------------------------
    def replan(self, new_cluster: ClusterSpec | None = None) -> PlanReport:
        """Re-run the pinned strategy on a changed device pool (paper
        §VI-C).  Live module weights migrate to their new hosts; the
        report lists the migration set (= switching cost)."""
        new_cluster = new_cluster if new_cluster is not None else self.cluster
        fn = get_placement(self._placement_name)

        def place(models, cluster):
            return fn(models, cluster, workload=self._workload,
                      **self._plan_opts)

        old = self.placement if self.placement is not None else Placement()
        new_pl, migrations = core_replan(
            self.models, self.cluster, new_cluster, old, place=place)
        self.cluster, self.placement = new_cluster, new_pl
        if self.engine is not None:
            self.engine.cluster = new_cluster
            self._extend_device_map()
            self._sync_engine()
        return self.report(migrations=migrations)

    def _extend_device_map(self) -> None:
        """A grown cluster brings placement device names the engine has
        never seen; back them with local jax devices so migrations to
        them actually execute instead of silently no-opping."""
        import jax

        devs = jax.devices()
        dm = self.engine.device_map
        for i, d in enumerate(self.cluster.devices):
            dm.setdefault(d.name, devs[i % len(devs)])

    def _sync_engine(self) -> list[tuple[str, str]]:
        """Align live runtimes with the current placement: re-route every
        module and jax.device_put the weights that moved."""
        eng = self.engine
        eng.placement = self.placement
        eng.routing = self._routing_name
        moves = []
        for name, rt in eng.runtimes.items():
            host = eng._host_for(name)
            if host and host != rt.host and host in eng.device_map:
                eng.migrate(name, host)
                moves.append((name, host))
        return moves
