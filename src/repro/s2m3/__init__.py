"""S2M3 public API: the ``Deployment`` facade and its policy registries.

This package is the stable entry point for split-and-share multi-task
inference — everything from model admission to placement, routing,
latency prediction, and live serving goes through ``Deployment``:

    from repro.s2m3 import Deployment, Request

    dep = (Deployment(cluster)
           .add_model(spec, builders)
           .plan(placement="greedy", routing="queue_aware", replicate=True)
           .materialize())
    report = dep.simulate(workload)     # predicted PlanReport
    result = dep.submit(workload[0])    # real compute, same Request
    results = dep.serve(workload)       # continuous-batching scheduler:
                                        # cross-task batches at shared
                                        # encoders, real queue-aware routing

Extension points: ``@register_placement`` / ``@register_routing`` add
named strategies without touching core.
"""

from repro.core.routing import QueueSnapshot, Request, SimResult  # noqa: F401
from repro.s2m3.deployment import Deployment, PlanReport  # noqa: F401
from repro.s2m3.policies import (  # noqa: F401
    RouteQuery,
    available_placements,
    available_routings,
    get_placement,
    get_routing,
    register_placement,
    register_routing,
)

__all__ = [
    "Deployment", "PlanReport", "Request", "SimResult", "QueueSnapshot",
    "RouteQuery",
    "available_placements", "available_routings",
    "get_placement", "get_routing",
    "register_placement", "register_routing",
]
