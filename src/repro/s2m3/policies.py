"""Named placement strategies and routing policies.

Both halves of the paper's planner become *registries* of callables with
a common signature, so ``s2m3.Deployment`` (and any future scheduler)
selects them by name instead of threading string-typed kwargs through
every layer:

* placement strategies — ``fn(models, cluster, *, workload=None,
  **opts) -> Placement``.  Built-ins: ``greedy`` (Algorithm 1),
  ``no_share`` (dedicated copies, the paper's sharing ablation),
  ``centralized`` (Cloud/Local baselines), ``optimal`` (brute-force
  Upper — needs ``workload``).
* routing policies — ``fn(RouteQuery) -> device name``.  Built-ins:
  ``paper`` (Eq. 7: min measured compute time) and ``queue_aware``
  (beyond-paper: min predicted completion including queueing).

The same routing policy object serves the event-driven simulator (full
queue state in the ``RouteQuery``) and the live engine, which is what
makes simulated and real module→device assignments comparable.  The
engine routes with an empty queue at deploy time; once a serving
scheduler is attached (``serving.scheduler.ServeScheduler`` sets
``engine.queue_probe``), ``RouteQuery.device_free`` carries the
scheduler's *live* per-host occupancy — a ``core.routing.QueueSnapshot``
— so ``queue_aware`` ranks replica hosts by real load.

Register your own with the ``@register_placement`` /
``@register_routing`` decorators.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Mapping

from repro.core.cluster import ClusterSpec
from repro.core.module import ModuleSpec
from repro.core.placement import (
    Placement, centralized_place, greedy_place, optimal_place,
)

# --------------------------------------------------------------------------
# routing
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class RouteQuery:
    """Everything a routing policy may consult when choosing among the
    devices hosting a module replica.  ``request`` / queue state are
    optional: the live engine routes with an empty queue at deploy time
    and with the serving scheduler's live occupancy under load."""

    module: ModuleSpec
    hosts: tuple[str, ...]
    cluster: ClusterSpec
    source: str | None = None
    request: Any = None                    # core.routing.Request or None
    ready_time: float = 0.0
    device_free: Mapping[str, float] = field(default_factory=dict)

    def work_mult(self, device) -> float:
        if self.request is None:
            return 1.0            # deploy-time routing: no request workload
        from repro.core.routing import work_multiplier

        return work_multiplier(self.request, self.module.modality, device)

    def t_comm_in(self, dname: str) -> float:
        if self.source is None:
            return 0.0
        return self.cluster.t_comm(self.source, dname, self.module.input_bytes)


RoutingPolicy = Callable[[RouteQuery], str]
PlacementStrategy = Callable[..., Placement]

_ROUTINGS: dict[str, RoutingPolicy] = {}
_PLACEMENTS: dict[str, PlacementStrategy] = {}


def register_routing(name: str) -> Callable[[RoutingPolicy], RoutingPolicy]:
    def deco(fn: RoutingPolicy) -> RoutingPolicy:
        _ROUTINGS[name] = fn
        return fn
    return deco


def get_routing(name: str) -> RoutingPolicy:
    try:
        return _ROUTINGS[name]
    except KeyError:
        raise KeyError(
            f"unknown routing policy {name!r}; "
            f"available: {available_routings()}") from None


def available_routings() -> tuple[str, ...]:
    return tuple(sorted(_ROUTINGS))


@register_routing("paper")
def route_paper(q: RouteQuery) -> str:
    """Eq. (7): hosting device with minimal measured compute time for
    this request's workload."""
    def key(dname: str) -> float:
        dev = q.cluster.device(dname)
        return q.cluster.t_comp(q.module, dev) * q.work_mult(dev)
    return min(q.hosts, key=key)


@register_routing("queue_aware")
def route_queue_aware(q: RouteQuery) -> str:
    """Beyond-paper: minimal predicted completion, counting the input
    transfer and the device's outstanding queue."""
    def key(dname: str) -> float:
        dev = q.cluster.device(dname)
        arrive = q.ready_time + q.t_comm_in(dname)
        return max(arrive, q.device_free.get(dname, 0.0)) \
            + q.cluster.t_comp(q.module, dev) * q.work_mult(dev)
    return min(q.hosts, key=key)


# --------------------------------------------------------------------------
# placement
# --------------------------------------------------------------------------


def register_placement(name: str) -> Callable[[PlacementStrategy],
                                              PlacementStrategy]:
    def deco(fn: PlacementStrategy) -> PlacementStrategy:
        _PLACEMENTS[name] = fn
        return fn
    return deco


def get_placement(name: str) -> PlacementStrategy:
    try:
        return _PLACEMENTS[name]
    except KeyError:
        raise KeyError(
            f"unknown placement strategy {name!r}; "
            f"available: {available_placements()}") from None


def available_placements() -> tuple[str, ...]:
    return tuple(sorted(_PLACEMENTS))


def strategy_options(fn: PlacementStrategy) -> tuple[str, ...] | None:
    """Keyword options a placement strategy accepts, for static typo
    checking of ``plan(**opts)``.  Returns ``None`` when the strategy
    declares a real ``**kwargs`` (anything goes — not checkable); the
    built-ins use the ``**_`` convention for "ignore options meant for
    other strategies", which *is* checkable."""
    import inspect

    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return None
    names = []
    for p in sig.parameters.values():
        if p.kind == inspect.Parameter.VAR_KEYWORD:
            if p.name != "_":
                return None
        elif p.kind in (inspect.Parameter.KEYWORD_ONLY,
                        inspect.Parameter.POSITIONAL_OR_KEYWORD):
            names.append(p.name)
    return tuple(names)


@register_placement("greedy")
def place_greedy(models, cluster, *, workload=None, replicate=False,
                 **_) -> Placement:
    """Algorithm 1: shared modules, completion-time-greedy first fit."""
    return greedy_place(models, cluster, share=True, replicate=replicate)


@register_placement("no_share")
def place_no_share(models, cluster, *, workload=None, replicate=False,
                   **_) -> Placement:
    """Sharing ablation (Table X): a dedicated module copy per model."""
    return greedy_place(models, cluster, share=False, replicate=replicate)


@register_placement("centralized")
def place_centralized(models, cluster, *, workload=None, device=None,
                      **_) -> Placement:
    """Everything on one device (Cloud/Local baselines).  ``device``
    defaults to the largest-memory device in the pool."""
    if device is None:
        device = max(cluster.devices, key=lambda d: d.mem_capacity).name
    return centralized_place(models, cluster, device)


@register_placement("optimal")
def place_optimal(models, cluster, *, workload=None, max_nodes=8,
                  **_) -> Placement:
    """Brute-force Upper baseline; requires the workload it optimizes."""
    if not workload:
        raise ValueError(
            "placement strategy 'optimal' needs workload=[Request, ...]")
    pl, _ = optimal_place(models, cluster, workload, max_nodes=max_nodes)
    return pl
