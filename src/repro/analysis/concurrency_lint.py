"""AST concurrency lint for the serving layer.

The ``ServeScheduler`` mutates shared state (queues, stats, in-flight
tables, the ``_free_at`` occupancy map) that a submitting producer and a
draining consumer may touch from different threads.  The discipline is:

* every attribute that is ever mutated under the instance's lock must
  *always* be mutated under it (outside ``__init__``) —
  ``concurrency/unlocked-mutation`` ERROR;
* JAX dispatch (``jax.*`` / ``jnp.*`` calls, ``apply_module`` /
  ``apply_head`` / ``infer`` / ``block_until_ready`` / ``device_put``)
  must not run while holding the lock: device calls are slow and
  re-entrant callbacks (``queue_probe``) would deadlock —
  ``concurrency/dispatch-under-lock`` WARNING;
* batch-coalescing paths (anything reachable from ``step`` /
  ``_service`` through self-calls) must not mutate the module registry
  (``add_model`` / ``remove_model`` / ``deploy_model`` /
  ``evict_model``): registry churn mid-batch invalidates the specs the
  batch was formed against — ``concurrency/registry-mutation-in-batch-path``
  ERROR;
* allocator mutations (``alloc`` / ``extend`` / ``free`` / ``release``
  on any self-rooted object — the page pool and row slots of a decode
  stream) must run under the lock: a free racing an alloc corrupts the
  free list and double-assigns pages —
  ``concurrency/unlocked-allocator-call`` ERROR;
* metrics instruments (any class declaring
  ``kind = "counter" | "gauge" | "histogram"`` — the ``obs.metrics``
  contract) must mutate their state only under their lock, *every*
  mutation, not just ones some other site happens to guard: instruments
  are shared across scheduler threads by construction —
  ``obs/unlocked-metric-mutation`` ERROR;
* serving and observability code must not read wall clocks directly
  (``time.time()`` / ``time.monotonic()``): both layers take an
  injected clock (``Tracer(clock=...)``, the scheduler's ``now=``) so
  simulated and real runs stay comparable and tests run on virtual
  time — ``obs/raw-clock-call`` WARNING, scoped to files under
  ``serving/`` and ``obs/``.

Scope and honesty: this is a lint, not an escape analysis.  It tracks
direct ``self.X`` mutations (assignment, augmented assignment, ``del``,
and mutating method calls such as ``append`` / ``pop`` / ``update`` /
``setdefault``); local aliases (``q = self.queues[m]; q.append(...)``)
are invisible to it.  Lock detection covers ``self.X = threading.Lock()
/ RLock() / Condition()`` and any ``with self.<attr>`` where the
attribute name contains "lock".
"""

from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis.diagnostics import Diagnostic, Severity

_LOCK_CTORS = {"Lock", "RLock", "Condition", "Semaphore",
               "BoundedSemaphore"}
_MUTATORS = {"append", "appendleft", "extend", "extendleft", "insert",
             "pop", "popleft", "popitem", "remove", "discard", "clear",
             "update", "setdefault", "add"}
_DISPATCH_ATTRS = {"device_put", "block_until_ready", "apply_module",
                   "apply_head", "infer", "apply", "apply_prefill",
                   "apply_paged_decode", "init_paged_cache", "generate"}
_ALLOC_MUTATORS = {"alloc", "extend", "free", "release"}
_DISPATCH_ROOTS = {"jax", "jnp"}
_REGISTRY_MUTATORS = {"add_model", "remove_model", "deploy_model",
                      "evict_model"}
_BATCH_ROOTS = {"step", "_service"}
_INSTRUMENT_KINDS = {"counter", "gauge", "histogram"}


def _instrument_kind(cls: ast.ClassDef) -> str | None:
    """The ``kind = "counter"`` class constant that marks an
    ``obs.metrics`` instrument class (None for everything else)."""
    for node in cls.body:
        if isinstance(node, ast.Assign) and isinstance(node.value,
                                                       ast.Constant):
            for t in node.targets:
                if (isinstance(t, ast.Name) and t.id == "kind"
                        and node.value.value in _INSTRUMENT_KINDS):
                    return node.value.value
    return None


def _self_attr(node) -> str | None:
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _root_name(node) -> str | None:
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _self_rooted(node) -> bool:
    """True when an attribute chain bottoms out at ``self``, looking
    through subscripts too (``self.decode[m].pool``)."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return isinstance(node, ast.Name) and node.id == "self"


def _is_lock_with(item: ast.withitem, lock_attrs: set[str]) -> bool:
    attr = _self_attr(item.context_expr)
    return attr is not None and (attr in lock_attrs
                                 or "lock" in attr.lower())


class _ClassFacts:
    def __init__(self) -> None:
        self.lock_attrs: set[str] = set()
        # (attr, method, lineno, under_lock)
        self.mutations: list[tuple[str, str, int, bool]] = []
        # (call description, method, lineno)
        self.locked_dispatch: list[tuple[str, str, int]] = []
        # (call description, method, lineno, under_lock)
        self.alloc_calls: list[tuple[str, str, int, bool]] = []
        self.self_calls: dict[str, set[str]] = {}
        self.registry_calls: dict[str, list[tuple[str, int]]] = {}
        self.methods: set[str] = set()


def _mutated_attr(stmt) -> list[str]:
    """Direct self.X mutations performed by one statement (not
    recursing into sub-statements)."""
    out = []
    if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
        targets = (stmt.targets if isinstance(stmt, ast.Assign)
                   else [stmt.target])
        for t in targets:
            a = _self_attr(t)
            if a is not None and isinstance(stmt, ast.AugAssign):
                out.append(a)
            elif a is not None and not isinstance(stmt, ast.Assign):
                pass                      # AnnAssign rebinding: see below
            if isinstance(t, (ast.Subscript,)):
                a = _self_attr(t.value)
                if a is not None:
                    out.append(a)         # self.X[k] = v / += v
            elif a is not None and isinstance(stmt, ast.Assign):
                out.append(a)             # self.X = v (rebinding)
    elif isinstance(stmt, ast.Delete):
        for t in stmt.targets:
            a = _self_attr(t)
            if a is not None:
                out.append(a)
            if isinstance(t, ast.Subscript):
                a = _self_attr(t.value)
                if a is not None:
                    out.append(a)
    # bare mutating calls (self.X.append(...) as a statement) are covered
    # by _call_mutations_in_expr — no Expr branch here, or they'd double
    return out


def _call_mutations_in_expr(node) -> list[tuple[str, int]]:
    """Mutating self.X.<mutator>(...) calls used as sub-expressions
    (e.g. ``q = self.queues.setdefault(...)``)."""
    out = []
    for call in ast.walk(node):
        if not isinstance(call, ast.Call):
            continue
        fn = call.func
        if isinstance(fn, ast.Attribute) and fn.attr in _MUTATORS:
            a = _self_attr(fn.value)
            if a is not None:
                out.append((a, call.lineno))
    return out


def _allocator_calls(node) -> list[tuple[str, int]]:
    """Self-rooted allocator-mutator calls (``self.pool.alloc(...)``,
    ``self.rows.release(...)``) — the decode substrate's free lists."""
    out = []
    for call in ast.walk(node):
        if not isinstance(call, ast.Call):
            continue
        fn = call.func
        if (isinstance(fn, ast.Attribute) and fn.attr in _ALLOC_MUTATORS
                and isinstance(fn.value, (ast.Attribute, ast.Subscript))
                and _self_rooted(fn.value)):
            out.append((ast.unparse(fn), call.lineno))
    return out


def _dispatch_calls(node) -> list[tuple[str, int]]:
    out = []
    for call in ast.walk(node):
        if not isinstance(call, ast.Call):
            continue
        fn = call.func
        if isinstance(fn, ast.Attribute):
            if fn.attr in _DISPATCH_ATTRS:
                out.append((fn.attr, call.lineno))
            elif _root_name(fn) in _DISPATCH_ROOTS:
                out.append((ast.unparse(fn), call.lineno))
    return out


def _collect_method(facts: _ClassFacts, method: ast.FunctionDef) -> None:
    name = method.name
    facts.methods.add(name)
    facts.self_calls.setdefault(name, set())
    facts.registry_calls.setdefault(name, [])

    def scan(node, under_lock: bool) -> None:
        """Record mutations/dispatch/calls in one statement or header
        expression — the caller guarantees ``node`` contains no nested
        statement bodies (those are recursed with their own lock ctx)."""
        for attr, ln in _call_mutations_in_expr(node):
            facts.mutations.append((attr, name, ln, under_lock))
        for desc, ln in _allocator_calls(node):
            facts.alloc_calls.append((desc, name, ln, under_lock))
        if under_lock:
            for desc, ln in _dispatch_calls(node):
                facts.locked_dispatch.append((desc, name, ln))
        for call in ast.walk(node):
            if isinstance(call, ast.Call):
                fn = call.func
                a = _self_attr(fn) if isinstance(fn, ast.Attribute) else None
                if a is not None:
                    facts.self_calls[name].add(a)
                cal = (fn.attr if isinstance(fn, ast.Attribute)
                       else fn.id if isinstance(fn, ast.Name) else None)
                if cal in _REGISTRY_MUTATORS:
                    facts.registry_calls[name].append((cal, call.lineno))

    def visit_block(stmts, under_lock: bool) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    scan(item.context_expr, under_lock)
                locked = under_lock or any(
                    _is_lock_with(i, facts.lock_attrs) for i in stmt.items)
                visit_block(stmt.body, locked)
            elif isinstance(stmt, (ast.If, ast.While)):
                scan(stmt.test, under_lock)
                visit_block(stmt.body, under_lock)
                visit_block(stmt.orelse, under_lock)
            elif isinstance(stmt, (ast.For, ast.AsyncFor)):
                scan(stmt.iter, under_lock)
                visit_block(stmt.body, under_lock)
                visit_block(stmt.orelse, under_lock)
            elif isinstance(stmt, ast.Try):
                visit_block(stmt.body, under_lock)
                for h in stmt.handlers:
                    visit_block(h.body, under_lock)
                visit_block(stmt.orelse, under_lock)
                visit_block(stmt.finalbody, under_lock)
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                visit_block(stmt.body, under_lock)
            else:
                for attr in _mutated_attr(stmt):
                    facts.mutations.append(
                        (attr, name, stmt.lineno, under_lock))
                scan(stmt, under_lock)

    visit_block(method.body, under_lock=False)


def _lint_class(cls: ast.ClassDef, filename: str) -> list[Diagnostic]:
    facts = _ClassFacts()
    methods = [n for n in cls.body
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]

    # pass 1: find lock attributes (ctor assignment or with-usage)
    for m in methods:
        for node in ast.walk(m):
            if isinstance(node, ast.Assign) and isinstance(node.value,
                                                           ast.Call):
                ctor = node.value.func
                ctor_name = (ctor.attr if isinstance(ctor, ast.Attribute)
                             else ctor.id if isinstance(ctor, ast.Name)
                             else None)
                if ctor_name in _LOCK_CTORS:
                    for t in node.targets:
                        a = _self_attr(t)
                        if a is not None:
                            facts.lock_attrs.add(a)
            elif isinstance(node, (ast.With, ast.AsyncWith)):
                for item in node.items:
                    a = _self_attr(item.context_expr)
                    if a is not None and "lock" in a.lower():
                        facts.lock_attrs.add(a)

    for m in methods:
        _collect_method(facts, m)

    diags: list[Diagnostic] = []
    loc = lambda ln: f"{filename}:{ln}"  # noqa: E731

    if facts.lock_attrs:
        guarded = {a for a, _, _, locked in facts.mutations if locked}
        for attr, meth, ln, locked in facts.mutations:
            if locked or meth == "__init__" or attr not in guarded:
                continue
            if attr in facts.lock_attrs:
                continue
            diags.append(Diagnostic(
                Severity.ERROR, "concurrency/unlocked-mutation",
                f"{cls.name}.{meth} mutates self.{attr} outside the lock, "
                f"but other sites guard it with "
                f"{sorted(facts.lock_attrs)}", entity=loc(ln),
                hint=f"wrap the mutation in `with self."
                     f"{sorted(facts.lock_attrs)[0]}:`"))
        for desc, meth, ln, locked in facts.alloc_calls:
            if locked or meth == "__init__":
                continue
            diags.append(Diagnostic(
                Severity.ERROR, "concurrency/unlocked-allocator-call",
                f"{cls.name}.{meth} calls {desc}(...) outside the lock; "
                "allocator free lists race against concurrent "
                "alloc/free and double-assign pages", entity=loc(ln),
                hint=f"hold `with self.{sorted(facts.lock_attrs)[0]}:` "
                     "across the allocator call"))
        for desc, meth, ln in facts.locked_dispatch:
            diags.append(Diagnostic(
                Severity.WARNING, "concurrency/dispatch-under-lock",
                f"{cls.name}.{meth} dispatches {desc}(...) while holding "
                "the lock; device calls under a lock serialize the "
                "scheduler and can deadlock re-entrant probes",
                entity=loc(ln),
                hint="form the batch under the lock, dispatch outside it"))

    kind = _instrument_kind(cls)
    if kind is not None:
        # instruments are shared across threads by construction: every
        # non-ctor mutation must hold the lock, whether or not any other
        # site guards that attribute
        for attr, meth, ln, locked in facts.mutations:
            if locked or meth == "__init__" or attr in facts.lock_attrs:
                continue
            diags.append(Diagnostic(
                Severity.ERROR, "obs/unlocked-metric-mutation",
                f"{cls.name} is a {kind} instrument (kind={kind!r}) but "
                f"{cls.name}.{meth} mutates self.{attr} outside the "
                "lock; concurrent scheduler threads would lose updates",
                entity=loc(ln),
                hint="hold `with self._lock:` across every instrument "
                     "mutation (see repro.obs.metrics)"))

    roots = _BATCH_ROOTS & facts.methods
    if roots:
        reachable = set(roots)
        frontier = list(roots)
        while frontier:
            m = frontier.pop()
            for callee in facts.self_calls.get(m, ()):
                if callee in facts.methods and callee not in reachable:
                    reachable.add(callee)
                    frontier.append(callee)
        for meth in sorted(reachable):
            for cal, ln in facts.registry_calls.get(meth, ()):
                diags.append(Diagnostic(
                    Severity.ERROR,
                    "concurrency/registry-mutation-in-batch-path",
                    f"{cls.name}.{meth} (reachable from "
                    f"{sorted(roots)}) calls {cal}(); mutating the "
                    "registry mid-batch invalidates the specs the batch "
                    "was formed against", entity=loc(ln),
                    hint="quiesce the scheduler (drain) before registry "
                         "changes — see Deployment.evict()/replan()"))
    return diags


_RAW_CLOCKS = {"time", "monotonic"}
_CLOCK_SCOPED_DIRS = {"serving", "obs"}


def _clock_scoped(filename: str) -> bool:
    parts = Path(filename).parts
    return bool(_CLOCK_SCOPED_DIRS & set(parts))


def _lint_raw_clocks(tree: ast.Module, filename: str) -> list[Diagnostic]:
    """``obs/raw-clock-call``: direct wall-clock reads in clock-injected
    layers (serving, obs)."""
    diags = []
    for call in ast.walk(tree):
        if not isinstance(call, ast.Call):
            continue
        fn = call.func
        if (isinstance(fn, ast.Attribute) and fn.attr in _RAW_CLOCKS
                and isinstance(fn.value, ast.Name)
                and fn.value.id == "time"):
            diags.append(Diagnostic(
                Severity.WARNING, "obs/raw-clock-call",
                f"direct time.{fn.attr}() call in a clock-injected layer; "
                "serving/obs code must read the injected clock so "
                "simulated and real runs stay comparable",
                entity=f"{filename}:{call.lineno}",
                hint="thread the constructor's `now`/`clock` callable "
                     "through instead (see Tracer(clock=...))"))
    return diags


def lint_source(src: str, filename: str = "<string>") -> list[Diagnostic]:
    try:
        tree = ast.parse(src, filename=filename)
    except SyntaxError as e:
        return [Diagnostic(
            Severity.ERROR, "concurrency/syntax-error",
            f"cannot parse {filename}: {e}", entity=filename)]
    diags: list[Diagnostic] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            diags.extend(_lint_class(node, filename))
    if _clock_scoped(filename):
        diags.extend(_lint_raw_clocks(tree, filename))
    return diags


def lint_paths(paths) -> list[Diagnostic]:
    """Lint .py files; directory arguments are walked recursively."""
    diags: list[Diagnostic] = []
    for p in paths:
        p = Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            diags.extend(lint_source(f.read_text(), filename=str(f)))
    return diags


def lint_serving() -> list[Diagnostic]:
    """Lint the in-tree serving layer (the default ``--self`` target)."""
    import repro.serving as serving

    return lint_paths([Path(serving.__file__).parent])
