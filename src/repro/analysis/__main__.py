"""CLI for the static analysis passes.

    python -m repro.analysis --self              # CI mode: lint the repro
                                                 # package + kernel sweep
    python -m repro.analysis src/repro/serving   # lint specific paths
    python -m repro.analysis --kernels           # kernel checker only

Exit status 1 when any ERROR-severity finding is emitted (WARNING/INFO
never fail the run).
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.diagnostics import errors, format_report


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static plan/kernel/concurrency analysis for the "
                    "S2M3 reproduction")
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files or directories to run the concurrency "
                         "lint over")
    ap.add_argument("--self", dest="self_mode", action="store_true",
                    help="lint the installed repro package sources and "
                         "run the zoo kernel sweep (the tier-1/CI mode)")
    ap.add_argument("--kernels", action="store_true",
                    help="run the Pallas kernel checker over the zoo's "
                         "shapes (jax.eval_shape only, no devices)")
    ap.add_argument("--vmem-budget", type=int, default=None, metavar="BYTES",
                    help="per-core VMEM budget for kernel working sets "
                         "(default 16 MiB)")
    args = ap.parse_args(argv)

    run_kernels = args.kernels or args.self_mode or not args.paths
    diags = []

    if args.self_mode:
        import repro

        from repro.analysis.concurrency_lint import lint_paths

        # repro may be a namespace package (__file__ is None): use __path__
        diags += lint_paths([Path(p) for p in repro.__path__])
    elif args.paths:
        from repro.analysis.concurrency_lint import lint_paths

        diags += lint_paths(args.paths)
    else:
        from repro.analysis.concurrency_lint import lint_serving

        diags += lint_serving()

    if run_kernels:
        from repro.analysis.kernel_check import check_kernels

        diags += check_kernels(vmem_budget=args.vmem_budget)

    print(format_report(diags))
    return 1 if errors(diags) else 0


if __name__ == "__main__":
    sys.exit(main())
