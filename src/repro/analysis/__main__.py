"""CLI for the static analysis passes.

    python -m repro.analysis --self              # CI mode: lint the repro
                                                 # package + kernel sweep
                                                 # + obs self-test
                                                 # + model-check + lockset
                                                 #   self-tests
                                                 # + bench regression gate
    python -m repro.analysis src/repro/serving   # lint specific paths
    python -m repro.analysis --kernels           # kernel checker only
    python -m repro.analysis --model-check       # explore the default
                                                 # serving scenario
    python -m repro.analysis --locksets          # interprocedural lockset
                                                 # race detection

``--self`` additionally runs the schedule-space model checker's
seeded-mutation self-test and the lockset detector's self-test, both
under the ``--mc-budget`` wall-clock cap, then re-runs the kernel and
serving benchmark sections and diffs them against the committed
``BENCH_*.json`` snapshots (``benchmarks/diff.py``); a latency metric
regressing beyond ``--bench-threshold`` fails the run just like an
ERROR finding.  Missing snapshots or a missing ``benchmarks/`` package
skip the gate with a note (installed-package layouts have no bench
tree).

Exit status 1 when any ERROR-severity finding is emitted (incl. a
model-check invariant violation) or the bench gate regresses
(WARNING/INFO never fail the run).
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.diagnostics import errors, format_report


def _bench_regressions(threshold: float):
    """Fresh-run the kernel + serving bench sections and diff them
    against the committed repo-root snapshots.  Returns
    ``(lines, failed)`` — human-readable report lines and whether any
    section regressed (or crashed)."""
    import json

    root = Path(__file__).resolve().parents[3]
    if not (root / "benchmarks").is_dir():
        return ["bench gate: no benchmarks/ package found, skipped"], False
    if str(root) not in sys.path:
        sys.path.insert(0, str(root))
    from benchmarks import kernels, serving
    from benchmarks.diff import (diff_snapshots, machine_profile,
                                 profile_mismatches)

    try:
        from benchmarks import analysis as bench_analysis
        sections = (("kernels", kernels.run), ("serving", serving.run),
                    ("analysis", bench_analysis.run))
    except ImportError:
        sections = (("kernels", kernels.run), ("serving", serving.run))

    lines, failed = [], False
    for name, fn in sections:
        snap = root / f"BENCH_{name}.json"
        if not snap.exists():
            lines.append(f"bench gate [{name}]: {snap.name} missing, "
                         "section skipped (run benchmarks/run.py)")
            continue
        old = json.loads(snap.read_text())
        mismatches = profile_mismatches(old.get("machine"),
                                        machine_profile())
        if mismatches:
            lines.append(
                f"bench gate [{name}]: snapshot recorded on a different "
                f"machine ({'; '.join(mismatches)}), section skipped — "
                "regenerate with benchmarks/run.py on this machine")
            continue
        try:
            new_rows = fn()
        except Exception as e:      # a crashed bench run is a failure
            lines.append(f"bench gate [{name}]: run crashed: "
                         f"{type(e).__name__}: {e}")
            failed = True
            continue
        regs, notes = diff_snapshots(
            old,
            {"section": name, "rows": list(new_rows)},
            threshold=threshold)
        lines += [f"bench gate [{name}]: {r.format()}" for r in regs]
        lines += [f"bench gate [{name}]: {n}" for n in notes]
        if regs:
            failed = True
        else:
            lines.append(f"bench gate [{name}]: ok "
                         f"(threshold {threshold:g}x)")
    return lines, failed


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static plan/kernel/concurrency analysis for the "
                    "S2M3 reproduction")
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files or directories to run the concurrency "
                         "lint over")
    ap.add_argument("--self", dest="self_mode", action="store_true",
                    help="lint the installed repro package sources and "
                         "run the zoo kernel sweep (the tier-1/CI mode)")
    ap.add_argument("--kernels", action="store_true",
                    help="run the Pallas kernel checker over the zoo's "
                         "shapes (jax.eval_shape only, no devices)")
    ap.add_argument("--vmem-budget", type=int, default=None, metavar="BYTES",
                    help="per-core VMEM budget for kernel working sets "
                         "(default 16 MiB)")
    ap.add_argument("--model-check", action="store_true",
                    help="exhaustively explore the default serving "
                         "scenario's schedule space against the invariant "
                         "catalog (exit 1 on a violation)")
    ap.add_argument("--locksets", action="store_true",
                    help="run the interprocedural lockset race detector "
                         "over the serving layer")
    ap.add_argument("--mc-budget", type=float, default=30.0,
                    metavar="SECONDS",
                    help="wall-clock cap for model-checker exploration "
                         "(and the --self model-check/lockset self-tests; "
                         "default 30)")
    ap.add_argument("--no-bench", action="store_true",
                    help="skip the benchmark regression gate in --self "
                         "mode")
    ap.add_argument("--bench-threshold", type=float, default=2.0,
                    metavar="RATIO",
                    help="fail when a bench latency metric exceeds "
                         "baseline * RATIO (default 2.0 — interpret-mode "
                         "wall clocks are noisy, this is a blowup "
                         "tripwire, not a perf SLO)")
    args = ap.parse_args(argv)

    run_kernels = args.kernels or args.self_mode or not args.paths
    diags = []

    if args.self_mode:
        import repro

        from repro.analysis import locksets, modelcheck
        from repro.analysis.concurrency_lint import lint_paths
        from repro.obs.selftest import self_test

        # repro may be a namespace package (__file__ is None): use __path__
        diags += lint_paths([Path(p) for p in repro.__path__])
        diags += self_test()
        # seeded-mutation self-tests: the model checker must catch every
        # injected serving bug and the unmutated tree must verify clean
        diags += modelcheck.self_test(budget_s=args.mc_budget)
        diags += locksets.self_test()
    elif args.paths:
        from repro.analysis.concurrency_lint import lint_paths

        diags += lint_paths(args.paths)
    else:
        from repro.analysis.concurrency_lint import lint_serving

        diags += lint_serving()

    if run_kernels:
        from repro.analysis.kernel_check import check_kernels

        diags += check_kernels(vmem_budget=args.vmem_budget)

    if args.model_check:
        from repro.analysis import modelcheck
        from repro.analysis.diagnostics import Diagnostic, Severity

        res = modelcheck.check(modelcheck.default_scenario(),
                               budget_s=args.mc_budget)
        if res.counterexample is not None:
            cx = res.counterexample
            diags.append(Diagnostic(
                Severity.ERROR, f"modelcheck/{cx.invariant}",
                f"{cx.message}\ncounterexample:\n{cx.format_script()}",
                entity="default_scenario"))
        else:
            diags.append(Diagnostic(
                Severity.INFO if res.complete else Severity.WARNING,
                "modelcheck/clean" if res.complete
                else "modelcheck/truncated",
                res.summary(), entity="default_scenario"))

    if args.locksets:
        from repro.analysis.locksets import lint_serving_locksets

        diags += lint_serving_locksets().diagnostics

    print(format_report(diags))

    bench_failed = False
    if args.self_mode and not args.no_bench:
        lines, bench_failed = _bench_regressions(args.bench_threshold)
        for line in lines:
            print(line)
    return 1 if errors(diags) or bench_failed else 0


if __name__ == "__main__":
    sys.exit(main())
