"""Explicit-state model checker for the serving stack's schedule space.

``tests/`` can only witness the interleavings a real run happens to
take; this module *enumerates* them.  It extracts an abstract state
machine from the real serving objects — the page pool free-list and
block tables, the decode-row slot pool, and registry refcounts are
**live instances** of ``PagePool`` / ``SlotPool`` / ``ModuleRegistry``,
so their guards (double-free, signature collisions, ``PagesExhausted``)
fire inside the model exactly as they would in production — and
explores every bounded interleaving of the serving transitions

    admit / form_batch / prefill / decode_tick / finish /
    reject / evict / replan

via BFS with state-fingerprint deduplication.  Every reached state is
checked against the declarative invariant catalog
(``repro.analysis.invariants``); the first violation is returned as a
:class:`Counterexample` holding the exact transition script that
reaches it.  Scripts are replayable (``replay()`` re-drives a fresh
model and must reproduce the violation) and exportable as Chrome
traces through ``repro.obs`` for timeline inspection.

The ``mutate=`` hook injects one of a fixed set of serving bugs
(dropped ``free()``, double free, skipped reservation, refcount skew,
unsafe evict, FIFO admission, sticky rows, mid-stream decoder moves) so
``self_test()`` can prove the checker actually catches each class of
bug while the unmutated machine verifies clean.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field, replace

from repro.analysis.diagnostics import Diagnostic, Severity
from repro.analysis.invariants import (DUMMY_SEQ, SeqView, StateView,
                                       WaitView, check_state)
from repro.core.module import ModelSpec, ModuleSpec
from repro.core.registry import ModuleRegistry
from repro.serving.kvcache import PagePool, PagesExhausted, SlotPool

#: mutation name -> invariant names that must flag it (any one suffices)
MUTATIONS: dict[str, tuple[str, ...]] = {
    # a dropped free first erodes the free list until admission math goes
    # unsound, then shows as a leak at drain — either attribution is the
    # same bug
    "drop-free": ("pages/no-leak", "admission/reservation-sound"),
    "double-free": ("pages/no-double-free",),
    "skip-reservation": ("admission/reservation-sound",),
    "refcount-skew": ("registry/refcount-consistent",),
    "unsafe-evict": ("registry/refcount-consistent",),
    "fifo-admission": ("slo/bounded-inversion",),
    "sticky-row": ("rows/slot-consistent", "sched/deadlock-free"),
    "move-decoder": ("registry/decoder-pinned",),
}


@dataclass(frozen=True)
class MCRequest:
    """One generative request in the bounded scenario."""

    rid: int
    model: str
    prompt_len: int = 2
    max_new: int = 2
    deadline: float = float("inf")


@dataclass(frozen=True)
class MCModel:
    """One registered model: encoder signatures + its decoder module."""

    name: str
    decoder: str
    encoders: tuple[str, ...] = ()


@dataclass(frozen=True)
class MCConfig:
    """A bounded serving scenario for the checker to exhaust."""

    requests: tuple[MCRequest, ...]
    models: tuple[MCModel, ...]
    rows: int = 2
    pages: int = 5
    page_size: int = 2
    n_prefix: int = 0
    max_queue_depth: int = 8          # reject enabled past this depth
    evictable: tuple[str, ...] = ()   # model names evict() may target
    replannable: tuple[str, ...] = ()  # decoder modules replan() may move
    hosts: tuple[str, ...] = ("edge0", "edge1")
    inversion_bound: int = 0
    max_states: int = 200_000
    max_depth: int = 400
    mutate: str | None = None         # a key of MUTATIONS, or None

    def __post_init__(self):
        if self.mutate is not None and self.mutate not in MUTATIONS:
            raise ValueError(f"unknown mutation {self.mutate!r}; "
                             f"known: {sorted(MUTATIONS)}")
        names = {m.name for m in self.models}
        for r in self.requests:
            if r.model not in names:
                raise ValueError(f"request {r.rid} targets unregistered "
                                 f"model {r.model!r}")

    def model(self, name: str) -> MCModel:
        return next(m for m in self.models if m.name == name)

    def model_specs(self) -> list[ModelSpec]:
        """Materialize real ModelSpecs so the model state can run a real
        ModuleRegistry (shared signatures and all)."""
        mods: dict[str, ModuleSpec] = {}

        def spec(name: str, kind: str, generative: bool = False):
            if name not in mods:
                mods[name] = ModuleSpec(name, kind, "text", n_params=1,
                                        generative=generative)
            return mods[name]

        return [ModelSpec(m.name, task=m.name,
                          encoders=tuple(spec(e, "encoder")
                                         for e in m.encoders),
                          head=spec(m.decoder, "head", True))
                for m in self.models]


@dataclass
class _Live:
    """A live (admitted) sequence in the model state."""

    rid: int
    row: int
    worst: int            # worst-case pages reserved at admission
    length: int           # tokens in the paged cache
    generated: int        # -1 = prefill pending, else tokens emitted
    host_at_admit: str


@dataclass
class _State:
    """One explored global state.  The pool / rows / registry members
    are real serving allocator instances, cloned per expansion."""

    pool: PagePool
    rows: SlotPool
    registry: ModuleRegistry
    arrived: tuple[int, ...]              # submitted, not batch-formed
    waiting: tuple[int, ...]              # decode queue, priority order
    live: dict[int, _Live] = field(default_factory=dict)
    finishable: tuple[int, ...] = ()      # fully decoded, free pending
    done: frozenset = frozenset()
    rejected: frozenset = frozenset()
    registered: tuple[str, ...] = ()      # ground-truth model names
    decoder_host: dict[str, str] = field(default_factory=dict)
    reserved: int = 0
    inversions: int = 0
    double_frees: tuple = ()
    depth: int = 0


def _clone_pool(p: PagePool) -> PagePool:
    q = PagePool(p.n_pages, p.page_size)
    q._free = list(p._free)
    q.tables = {k: list(v) for k, v in p.tables.items()}
    q.used_tokens = dict(p.used_tokens)
    q.pages_peak = p.pages_peak
    return q


def _clone_rows(r: SlotPool) -> SlotPool:
    s = SlotPool(r.max_slots)
    s._free = list(r._free)
    s.lengths = list(r.lengths)
    s.live = list(r.live)
    return s


def _clone_registry(r: ModuleRegistry) -> ModuleRegistry:
    s = ModuleRegistry()
    s._models = dict(r._models)
    for name, e in r._entries.items():
        s._entries[name] = type(e)(e.module, set(e.refs))
    return s


def _clone(st: _State) -> _State:
    return _State(
        pool=_clone_pool(st.pool), rows=_clone_rows(st.rows),
        registry=_clone_registry(st.registry),
        arrived=st.arrived, waiting=st.waiting,
        live={k: replace(v) for k, v in st.live.items()},
        finishable=st.finishable, done=st.done, rejected=st.rejected,
        registered=st.registered, decoder_host=dict(st.decoder_host),
        reserved=st.reserved, inversions=st.inversions,
        double_frees=st.double_frees, depth=st.depth)


def _fingerprint(st: _State) -> tuple:
    """Canonical state key.  Page *identity* is abstracted away (only
    per-sequence held counts and the free count matter), so LIFO
    recycling order does not blow up the state space."""
    return (
        st.arrived, st.waiting,
        tuple(sorted((l.rid, l.row, l.length, l.generated, l.worst)
                     for l in st.live.values())),
        tuple(sorted(st.finishable)),
        tuple(sorted(st.done)), tuple(sorted(st.rejected)),
        st.pool.n_free,
        tuple(sorted((str(s), len(t)) for s, t in st.pool.tables.items())),
        st.rows.n_live, st.registered,
        tuple(sorted(st.registry._models)),
        tuple(sorted((m, st.registry.refcount(m))
                     for m in st.registry.modules)),
        tuple(sorted(st.decoder_host.items())),
        st.reserved, st.inversions, len(st.double_frees),
    )


class SchedulingModel:
    """The abstract serving machine: initial state + enabled/apply."""

    def __init__(self, cfg: MCConfig):
        self.cfg = cfg
        self.req = {r.rid: r for r in cfg.requests}
        self.specs = {s.name: s for s in cfg.model_specs()}
        self.decoder_of = {m.name: m.decoder for m in cfg.models}

    # -- sizing, mirroring DecodeStream ---------------------------------
    def _prefix_len(self, r: MCRequest) -> int:
        return self.cfg.n_prefix + r.prompt_len

    def _worst_pages(self, r: MCRequest, pool: PagePool) -> int:
        return pool.pages_for(self._prefix_len(r) + max(r.max_new, 1))

    def initial(self) -> _State:
        pool = PagePool(self.cfg.pages, self.cfg.page_size)
        pool.alloc(DUMMY_SEQ, 1)        # dead rows scatter here
        registry = ModuleRegistry()
        for s in self.specs.values():
            registry.add_model(s)
        hosts = {m.decoder: self.cfg.hosts[0] for m in self.cfg.models}
        return _State(pool=pool, rows=SlotPool(self.cfg.rows),
                      registry=registry,
                      arrived=tuple(r.rid for r in self.cfg.requests),
                      waiting=(), registered=tuple(sorted(self.specs)),
                      decoder_host=hosts)

    # -- transition enumeration ------------------------------------------
    def enabled(self, st: _State) -> list[tuple[str, object]]:
        cfg, out = self.cfg, []
        mut = cfg.mutate
        if st.arrived:
            out.append(("form_batch", None))
            if len(st.waiting) + len(st.live) >= cfg.max_queue_depth:
                out.append(("reject", st.arrived[-1]))
        if st.waiting and self._admittable(st) is not None:
            out.append(("admit", self._admittable(st)))
        out += [("prefill", l.rid) for l in st.live.values()
                if l.generated < 0]
        if any(l.generated >= 1 and l.rid not in st.finishable
               for l in st.live.values()):
            out.append(("decode_tick", None))
        out += [("finish", rid) for rid in st.finishable]
        inflight = self._inflight(st)
        for name in cfg.evictable:
            if name not in st.registered:
                continue
            if mut != "unsafe-evict" and name in inflight:
                continue
            out.append(("evict", name))
        for mod in cfg.replannable:
            pinned = any(self.decoder_of[self.req[l.rid].model] == mod
                         for l in st.live.values())
            if mut != "move-decoder" and pinned:
                continue
            cur = st.decoder_host.get(mod)
            nxt = next((h for h in cfg.hosts if h != cur), None)
            if nxt is not None:
                out.append(("replan", mod))
        return out

    def _inflight(self, st: _State) -> set:
        rids = (set(st.arrived) | set(st.waiting) | set(st.live)
                | set(st.finishable))
        return {self.req[r].model for r in rids}

    def _admittable(self, st: _State) -> int | None:
        """rid the admission policy would admit next, or None.  Mirrors
        ``DecodeStream._pop_admittable``: head-of-heap only, row + full
        worst-case reservation must fit."""
        if not st.waiting:
            return None
        head = (st.waiting[0] if self.cfg.mutate != "fifo-admission"
                else min(st.waiting))       # FIFO bug: arrival order
        r = self.req[head]
        if st.rows.n_live >= st.rows.max_slots:
            return None
        worst = self._worst_pages(r, st.pool)
        if self.cfg.mutate == "skip-reservation":
            # bug: only checks the immediate prefill allocation, not the
            # outstanding worst-case demand of everything already live
            need = max(st.pool.pages_for(self._prefix_len(r)), 1)
            return head if need <= st.pool.n_free else None
        held = st.pool.n_live_pages - 1          # minus the dummy page
        if st.pool.n_free - (st.reserved - held) < worst:
            return None
        return head

    # -- transition application -------------------------------------------
    def apply(self, st: _State, name: str, arg) -> _State:
        st = _clone(st)
        st.depth += 1
        getattr(self, f"_t_{name}")(st, arg)
        return st

    def _t_form_batch(self, st: _State, _):
        """ServeScheduler batch formation: arrived requests enter the
        decode queue, which orders by (deadline, arrival)."""
        merged = list(st.waiting) + list(st.arrived)
        merged.sort(key=lambda rid: (self.req[rid].deadline, rid))
        st.waiting, st.arrived = tuple(merged), ()

    def _t_reject(self, st: _State, rid: int):
        st.arrived = tuple(r for r in st.arrived if r != rid)
        st.rejected = st.rejected | {rid}

    def _t_admit(self, st: _State, rid: int):
        r = self.req[rid]
        st.waiting = tuple(x for x in st.waiting if x != rid)
        # a request admitted past an earlier-deadline waiter is a
        # priority inversion (impossible head-of-heap, possible FIFO)
        st.inversions += sum(
            1 for w in st.waiting if self.req[w].deadline < r.deadline)
        row = st.rows.alloc()
        prefix = self._prefix_len(r)
        st.pool.alloc(rid, prefix)
        worst = self._worst_pages(r, st.pool)
        if self.cfg.mutate != "skip-reservation":
            st.reserved += worst
        dec = self.decoder_of[r.model]
        st.live[rid] = _Live(rid, row, worst, prefix, -1,
                             st.decoder_host[dec])

    def _t_prefill(self, st: _State, rid: int):
        l = st.live[rid]
        l.generated = 1                  # prefill emits the first token
        if l.generated >= max(self.req[rid].max_new, 1):
            st.finishable = st.finishable + (rid,)

    def _t_decode_tick(self, st: _State, _):
        """One batched decode step over every live, prefetched row —
        exactly DecodeStream._decode_once's accounting."""
        for l in sorted(st.live.values(), key=lambda x: x.row):
            if l.generated < 1 or l.rid in st.finishable:
                continue
            st.pool.extend(l.rid, l.length + 1)
            l.length += 1
            l.generated += 1
            if l.generated >= max(self.req[l.rid].max_new, 1):
                st.finishable = st.finishable + (l.rid,)

    def _t_finish(self, st: _State, rid: int):
        """DecodeStream._finish_locked — the mutations nest here."""
        l = st.live.pop(rid)
        mut = self.cfg.mutate
        if mut != "drop-free":
            st.pool.free(rid)
        if mut == "double-free":
            try:
                st.pool.free(rid)
            except ValueError:
                st.double_frees = st.double_frees + (rid,)
        if mut != "sticky-row":
            st.rows.release(l.row)
        if mut != "skip-reservation":
            st.reserved -= l.worst
        st.finishable = tuple(x for x in st.finishable if x != rid)
        st.done = st.done | {rid}

    def _t_evict(self, st: _State, name: str):
        st.registered = tuple(m for m in st.registered if m != name)
        if self.cfg.mutate == "refcount-skew":
            # bug: drops the model entry without releasing module refs
            st.registry._models.pop(name, None)
        else:
            st.registry.remove_model(name)

    def _t_replan(self, st: _State, mod: str):
        cur = st.decoder_host[mod]
        st.decoder_host[mod] = next(h for h in self.cfg.hosts if h != cur)

    # -- invariant view -----------------------------------------------------
    def view(self, st: _State,
             enabled: list[tuple[str, object]] | None = None) -> StateView:
        pool = st.pool
        owners: dict[int, object] = {}
        multi: list[int] = []
        for seq, pages in pool.tables.items():
            for p in pages:
                if p in owners or p in pool._free:
                    multi.append(p)
                owners[p] = seq
        live = tuple(
            SeqView(rid=l.rid, held_pages=len(pool.tables.get(l.rid, ())),
                    worst_pages=l.worst,
                    remaining_tokens=max(
                        self.req[l.rid].max_new - max(l.generated, 0), 0),
                    deadline=self.req[l.rid].deadline,
                    model=self.req[l.rid].model,
                    host=st.decoder_host.get(
                        self.decoder_of[self.req[l.rid].model]),
                    host_at_admit=l.host_at_admit)
            for l in st.live.values())
        waiting = tuple(
            WaitView(rid=rid,
                     worst_pages=self._worst_pages(self.req[rid], pool),
                     deadline=self.req[rid].deadline,
                     model=self.req[rid].model)
            for rid in st.arrived + st.waiting)
        module_models = {
            mod: tuple(m.name for m in self.cfg.models
                       if m.name in st.registered
                       and mod in (m.decoder, *m.encoders))
            for m2 in self.cfg.models if m2.name in st.registered
            for mod in (m2.decoder, *m2.encoders)}
        deployed = tuple(sorted({
            self.decoder_of[self.req[l.rid].model] for l in st.live.values()}))
        terminal = (not st.arrived and not st.waiting and not st.live
                    and not st.finishable)
        return StateView(
            pages_total=pool.n_pages, pages_free=pool.n_free,
            page_owners=owners, page_multiowner=tuple(multi),
            page_size=pool.page_size,
            rows_total=st.rows.max_slots, rows_live=st.rows.n_live,
            live=live, waiting=waiting,
            refcounts={m: st.registry.refcount(m)
                       for m in module_models},
            module_models=module_models, deployed=deployed,
            inflight_models=tuple(sorted(self._inflight(st))),
            registered_models=st.registered,
            enabled=(tuple(n for n, _ in enabled)
                     if enabled is not None else ()),
            terminal=terminal,
            inversions=st.inversions,
            inversion_bound=self.cfg.inversion_bound,
            double_frees=st.double_frees)


# ---------------------------------------------------------------------------
# counterexamples
# ---------------------------------------------------------------------------

@dataclass
class Counterexample:
    """A replayable transition script reaching an invariant violation."""

    invariant: str
    message: str
    script: tuple[tuple[str, object], ...]

    def format_script(self) -> str:
        lines = [f"violates {self.invariant}: {self.message}", "script:"]
        lines += [f"  {i:3d}. {name}"
                  + (f"({arg!r})" if arg is not None else "()")
                  for i, (name, arg) in enumerate(self.script, 1)]
        return "\n".join(lines)

    def to_chrome_trace(self) -> dict:
        """Export the script as a Chrome trace over a virtual clock
        (one tick per transition) via repro.obs."""
        from repro.obs.trace import Tracer
        step = {"t": 0.0}
        tracer = Tracer(clock=lambda: step["t"])
        for name, arg in self.script:
            rid = arg if isinstance(arg, int) else None
            tracer.record("modelcheck", name, step["t"], step["t"] + 1.0,
                          rid=rid, arg=str(arg))
            step["t"] += 1.0
        tracer.record("modelcheck", "violation", step["t"],
                      step["t"] + 1.0, invariant=self.invariant,
                      message=self.message)
        return tracer.trace.to_chrome_trace()

    def save_trace(self, path) -> None:
        import json
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, indent=1)


@dataclass
class MCResult:
    states: int
    transitions: int
    elapsed_s: float
    complete: bool                     # frontier exhausted within budget
    counterexample: Counterexample | None
    config: MCConfig

    @property
    def ok(self) -> bool:
        return self.counterexample is None

    def summary(self) -> str:
        rate = self.states / self.elapsed_s if self.elapsed_s > 0 else 0.0
        verdict = ("no invariant violation" if self.ok
                   else f"VIOLATION of {self.counterexample.invariant}")
        return (f"model check: {self.states} states, "
                f"{self.transitions} transitions in {self.elapsed_s:.2f}s "
                f"({rate:,.0f} states/s, "
                f"{'complete' if self.complete else 'BUDGET-CAPPED'}) "
                f"-> {verdict}")


def check(cfg: MCConfig, *, budget_s: float | None = None) -> MCResult:
    """Exhaust the schedule space of ``cfg`` (BFS, fingerprint dedup),
    checking every reached state against the invariant catalog.  Stops
    at the first violation, the state/depth caps, or ``budget_s``."""
    model = SchedulingModel(cfg)
    t0 = time.monotonic()
    init = model.initial()
    frontier: deque[tuple[_State, tuple]] = deque([(init, ())])
    seen = {_fingerprint(init)}
    states = transitions = 0
    complete = True

    while frontier:
        if budget_s is not None and time.monotonic() - t0 > budget_s:
            complete = False
            break
        if states >= cfg.max_states:
            complete = False
            break
        st, script = frontier.popleft()
        states += 1
        enabled = model.enabled(st)
        violations = check_state(model.view(st, enabled),
                                 where="model-check")
        if violations:
            name, msg = violations[0]
            return MCResult(states, transitions,
                            time.monotonic() - t0, False,
                            Counterexample(name, msg, script), cfg)
        if st.depth >= cfg.max_depth:
            complete = False
            continue
        for name, arg in enabled:
            try:
                nxt = model.apply(st, name, arg)
            except PagesExhausted as e:
                # reservation soundness should make this unreachable;
                # if a mutation slips past the state check, surface it
                return MCResult(
                    states, transitions, time.monotonic() - t0, False,
                    Counterexample("admission/reservation-sound", str(e),
                                   script + ((name, arg),)), cfg)
            transitions += 1
            fp = _fingerprint(nxt)
            if fp not in seen:
                seen.add(fp)
                frontier.append((nxt, script + ((name, arg),)))
    return MCResult(states, transitions, time.monotonic() - t0,
                    complete, None, cfg)


def replay(cfg: MCConfig, script) -> list[tuple[str, str]]:
    """Re-drive a fresh model through a counterexample script and return
    the violations observed in the final state — regression tests call
    this to pin the exact interleaving a fix addresses."""
    model = SchedulingModel(cfg)
    st = model.initial()
    for i, (name, arg) in enumerate(script):
        if (name, arg) not in model.enabled(st):
            raise ValueError(
                f"replay step {i}: {name}({arg!r}) not enabled "
                f"(enabled: {model.enabled(st)})")
        try:
            st = model.apply(st, name, arg)
        except PagesExhausted as e:
            # same mapping as check(): an allocator crash mid-script IS
            # the reservation-soundness failure
            return [("admission/reservation-sound", str(e))]
    return check_state(model.view(st, model.enabled(st)),
                       where="model-check")


# ---------------------------------------------------------------------------
# scenarios
# ---------------------------------------------------------------------------

def default_scenario(*, mutate: str | None = None,
                     max_states: int = 200_000) -> MCConfig:
    """Two models sharing one decoder, three SLO-skewed requests, two
    rows, a page pool tight enough that reservations matter, one
    evictable model and a replannable decoder — small enough to exhaust
    in well under a second, rich enough that every mutation in
    ``MUTATIONS`` reaches its invariant violation."""
    return MCConfig(
        requests=(
            MCRequest(rid=1, model="chat", prompt_len=2, max_new=2,
                      deadline=5.0),
            MCRequest(rid=2, model="summarize", prompt_len=2, max_new=2,
                      deadline=1.0),
            # the long request's 3-page worst case is what makes
            # skipping the reservation check observable: admitting it
            # early strands the short requests' outstanding demand
            MCRequest(rid=3, model="chat", prompt_len=2, max_new=4),
        ),
        models=(MCModel("chat", decoder="lm", encoders=("text-enc",)),
                MCModel("summarize", decoder="lm", encoders=("text-enc",))),
        rows=2, pages=5, page_size=2,
        max_queue_depth=2,
        evictable=("summarize",), replannable=("lm",),
        mutate=mutate, max_states=max_states)


def scenario_from_deployment(dep, *, n_requests: int = 3,
                             mutate: str | None = None) -> MCConfig:
    """Derive a bounded scenario from a real ``Deployment``: its
    registered models and shared modules become the machine's registry;
    request sizes stay tiny so the schedule space stays exhaustible."""
    models = []
    for name, spec in sorted(dep.registry.models.items()):
        gen = [m.name for m in spec.modules if m.generative]
        models.append(MCModel(
            name, decoder=gen[0] if gen else f"{spec.head.name}",
            encoders=tuple(e.name for e in spec.encoders)))
    if not models:
        raise ValueError("deployment has no registered models to check")
    reqs = tuple(
        MCRequest(rid=i + 1, model=models[i % len(models)].name,
                  prompt_len=2, max_new=2,
                  deadline=float(i + 1) if i % 2 == 0 else float("inf"))
        for i in range(n_requests))
    evictable = (models[-1].name,) if len(models) > 1 else ()
    return MCConfig(requests=reqs, models=tuple(models),
                    rows=2, pages=2 * n_requests + 1, page_size=2,
                    max_queue_depth=2, evictable=evictable,
                    mutate=mutate)


# ---------------------------------------------------------------------------
# seeded-mutation self-test
# ---------------------------------------------------------------------------

def self_test(*, budget_s: float = 60.0) -> list[Diagnostic]:
    """Prove the checker catches every seeded serving bug and that the
    unmutated machine verifies clean.  Returns Diagnostics (ERROR on a
    missed mutation, spurious violation, or budget overrun)."""
    diags: list[Diagnostic] = []
    t0 = time.monotonic()

    def left() -> float:
        return max(budget_s - (time.monotonic() - t0), 0.1)

    clean = check(default_scenario(), budget_s=left())
    if not clean.ok:
        diags.append(Diagnostic(
            Severity.ERROR, "modelcheck/unclean-baseline",
            "unmutated serving model violates "
            f"{clean.counterexample.invariant}: "
            f"{clean.counterexample.message}",
            entity="default_scenario",
            hint=clean.counterexample.format_script()))
    elif not clean.complete:
        diags.append(Diagnostic(
            Severity.ERROR, "modelcheck/budget-exceeded",
            f"baseline exploration hit the budget after {clean.states} "
            "states without exhausting the schedule space",
            entity="default_scenario"))
    else:
        diags.append(Diagnostic(
            Severity.INFO, "modelcheck/clean",
            f"baseline clean: {clean.summary()}",
            entity="default_scenario"))

    for mut, expected in MUTATIONS.items():
        res = check(default_scenario(mutate=mut), budget_s=left())
        cx = res.counterexample
        if cx is None:
            diags.append(Diagnostic(
                Severity.ERROR, "modelcheck/mutation-missed",
                f"seeded bug {mut!r} explored {res.states} states "
                f"without tripping any of {expected}",
                entity=mut,
                hint="the checker lost coverage of this bug class"))
            continue
        if cx.invariant not in expected:
            diags.append(Diagnostic(
                Severity.ERROR, "modelcheck/mutation-misattributed",
                f"seeded bug {mut!r} tripped {cx.invariant} "
                f"(expected one of {expected}): {cx.message}",
                entity=mut))
            continue
        # the counterexample must replay: same script, same violation
        replayed = replay(default_scenario(mutate=mut), cx.script)
        if cx.invariant not in {n for n, _ in replayed}:
            diags.append(Diagnostic(
                Severity.ERROR, "modelcheck/replay-divergence",
                f"counterexample for {mut!r} does not reproduce "
                f"{cx.invariant} on replay",
                entity=mut, hint=cx.format_script()))
            continue
        diags.append(Diagnostic(
            Severity.INFO, "modelcheck/mutation-caught",
            f"seeded bug {mut!r} caught by {cx.invariant} after "
            f"{res.states} states ({len(cx.script)}-step counterexample)",
            entity=mut))
    return diags
