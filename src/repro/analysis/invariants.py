"""Declarative invariant catalog for the serving stack.

One ``@invariant``-registered predicate per safety property, shared by
three enforcement layers so simulation, static checking, and live
serving all guard the *same* contracts:

* the **model checker** (``repro.analysis.modelcheck``) evaluates the
  catalog at every explored state of its abstract serving machine;
* the **scheduler** (``serving.scheduler.ServeScheduler``) evaluates the
  runtime-tagged subset as debug assertions while draining;
* the **plan verifier** reports the static-tagged subset through
  ``Deployment.verify()``.

Every predicate consumes a ``StateView`` — a plain-data snapshot of the
shared serving state (page pool, decode rows, reservations, registry
refcounts) that each layer knows how to produce: the model checker from
its explored states, ``DecodeStream.state_view()`` from live objects.
Predicates return a list of violation messages (empty = holds) and must
be pure: no mutation, no device work, stdlib only.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

#: sequence key of the reserved scatter target page (never freed)
DUMMY_SEQ = "<dummy>"


@dataclass(frozen=True)
class SeqView:
    """One live (admitted) sequence's accounting, as the invariants see
    it: held vs worst-case reserved pages, decode progress, SLO."""

    rid: int
    held_pages: int              # pages currently in its block table
    worst_pages: int             # worst-case reservation made at admission
    remaining_tokens: int        # decode budget still outstanding
    deadline: float = float("inf")
    model: str | None = None
    host: str | None = None          # decoder host serving this sequence
    host_at_admit: str | None = None


@dataclass(frozen=True)
class WaitView:
    """One waiting (not yet admitted) sequence."""

    rid: int
    worst_pages: int
    deadline: float = float("inf")
    model: str | None = None


@dataclass
class StateView:
    """Plain-data snapshot of the shared serving state.

    Producers fill what they know; fields left at their defaults (None)
    make the invariants that need them report nothing, so one catalog
    serves partial runtime views and complete model-checker states.
    """

    # -- page pool ------------------------------------------------------
    pages_total: int | None = None
    pages_free: int | None = None
    # owning sequence per live page (the dummy page owns itself under
    # DUMMY_SEQ); a page listed twice upstream must be collapsed by the
    # producer into page_multiowner instead
    page_owners: dict[int, object] = field(default_factory=dict)
    # pages observed under >1 owner (or owned *and* free) — a producer
    # that detects double accounting reports the page ids here
    page_multiowner: tuple[int, ...] = ()
    page_size: int | None = None

    # -- decode rows / sequences ---------------------------------------
    rows_total: int | None = None
    rows_live: int | None = None
    live: tuple[SeqView, ...] = ()
    waiting: tuple[WaitView, ...] = ()

    # -- registry -------------------------------------------------------
    # module -> refcount claimed by the registry
    refcounts: dict[str, int] | None = None
    # module -> names of registered models referencing it (ground truth)
    module_models: dict[str, tuple[str, ...]] | None = None
    # modules with live runtimes (weights deployed)
    deployed: tuple[str, ...] = ()
    # models with requests currently in flight
    inflight_models: tuple[str, ...] = ()
    registered_models: tuple[str, ...] | None = None

    # -- scheduling -----------------------------------------------------
    # transitions enabled in this state (model checker only; None at
    # runtime, where the enabled set is unknowable)
    enabled: tuple[str, ...] | None = None
    # True when no pending work remains (all requests terminal)
    terminal: bool = False
    # SLO priority-inversion event count and its allowed bound
    inversions: int = 0
    inversion_bound: int = 0
    # pages freed for a sequence that did not own them (double free),
    # as detected by the producer (PagePool raises; the model records)
    double_frees: tuple[object, ...] = ()


@dataclass(frozen=True)
class Invariant:
    """One registered safety property."""

    name: str                    # stable "<layer>/<rule>" id
    layer: str                   # pages | admission | registry | sched | slo
    checked_by: tuple[str, ...]  # subset of {"model-check","runtime","static"}
    doc: str
    fn: Callable[[StateView], list[str]]


_CATALOG: dict[str, Invariant] = {}


def invariant(name: str, *, layer: str,
              checked_by: tuple[str, ...] = ("model-check",)):
    """Register a predicate in the catalog.  The decorated function
    takes a ``StateView`` and returns violation messages."""

    def deco(fn: Callable[[StateView], list[str]]):
        if name in _CATALOG:
            raise ValueError(f"invariant {name!r} registered twice")
        _CATALOG[name] = Invariant(name, layer, tuple(checked_by),
                                   (fn.__doc__ or "").strip(), fn)
        return fn

    return deco


def catalog() -> list[Invariant]:
    return sorted(_CATALOG.values(), key=lambda i: i.name)


def get(name: str) -> Invariant:
    return _CATALOG[name]


def check_state(view: StateView, *, where: str | None = None,
                names=None) -> list[tuple[str, str]]:
    """Evaluate the catalog against one state.  Returns
    ``(invariant_name, violation_message)`` pairs; ``where`` restricts
    to invariants tagged for that enforcement layer."""
    out: list[tuple[str, str]] = []
    for inv in catalog():
        if where is not None and where not in inv.checked_by:
            continue
        if names is not None and inv.name not in names:
            continue
        for msg in inv.fn(view):
            out.append((inv.name, msg))
    return out


def catalog_table() -> str:
    """The ROADMAP-style invariant table: name, layer, checked-by."""
    rows = [f"{i.name:32s} {i.layer:10s} {' / '.join(i.checked_by)}"
            for i in catalog()]
    return "\n".join(rows)


# ---------------------------------------------------------------------------
# the catalog
# ---------------------------------------------------------------------------

@invariant("pages/no-double-free", layer="pages",
           checked_by=("model-check", "runtime"))
def _no_double_free(v: StateView) -> list[str]:
    """No page is ever freed by a sequence that does not own it, and no
    page has more than one owner — the double-free guard ``PagePool``
    enforces dynamically, as a state predicate."""
    out = [f"sequence {s!r} freed pages it did not own"
           for s in v.double_frees]
    out += [f"page {p} has multiple owners" for p in v.page_multiowner]
    return out


@invariant("pages/conservation", layer="pages",
           checked_by=("model-check", "runtime"))
def _conservation(v: StateView) -> list[str]:
    """Every page is either on the free list or owned by exactly one
    sequence: free + held == total, always."""
    if v.pages_total is None or v.pages_free is None:
        return []
    held = len(v.page_owners)
    if v.pages_free + held != v.pages_total:
        return [f"page conservation broken: {v.pages_free} free + "
                f"{held} held != {v.pages_total} total "
                "(leak or double accounting)"]
    return []


@invariant("pages/no-leak", layer="pages",
           checked_by=("model-check", "runtime"))
def _no_leak(v: StateView) -> list[str]:
    """A quiescent pool (no live or waiting sequences) holds no pages
    beyond the reserved dummy page."""
    if not v.terminal or v.pages_total is None:
        return []
    leaked = {p: s for p, s in v.page_owners.items() if s != DUMMY_SEQ}
    if leaked:
        owners = sorted({str(s) for s in leaked.values()})
        return [f"{len(leaked)} page(s) leaked after drain "
                f"(still owned by {owners})"]
    return []


@invariant("admission/reservation-sound", layer="admission",
           checked_by=("model-check", "runtime"))
def _reservation_sound(v: StateView) -> list[str]:
    """An admitted sequence can never fail a mid-stream allocation: the
    free list always covers every live sequence's outstanding
    worst-case demand (``PagesExhausted`` is statically unreachable)."""
    if v.pages_free is None or not v.live:
        return []
    outstanding = sum(max(s.worst_pages - s.held_pages, 0) for s in v.live)
    if v.pages_free < outstanding:
        return [f"reservation unsound: {v.pages_free} page(s) free < "
                f"{outstanding} outstanding worst-case demand across "
                f"{len(v.live)} live sequence(s) — a decode extend can "
                "hit PagesExhausted"]
    return []


@invariant("rows/slot-consistent", layer="pages",
           checked_by=("model-check", "runtime"))
def _rows_consistent(v: StateView) -> list[str]:
    """Live decode rows always equal live sequences and never exceed
    capacity (a skewed slot pool double-assigns batch rows)."""
    if v.rows_total is None or v.rows_live is None:
        return []
    out = []
    if v.rows_live != len(v.live):
        out.append(f"slot pool skew: {v.rows_live} live row(s) vs "
                   f"{len(v.live)} live sequence(s)")
    if not 0 <= v.rows_live <= v.rows_total:
        out.append(f"slot pool corrupt: {v.rows_live} live of "
                   f"{v.rows_total} rows")
    return out


@invariant("registry/refcount-consistent", layer="registry",
           checked_by=("model-check", "runtime", "static"))
def _refcounts(v: StateView) -> list[str]:
    """Module refcounts equal the number of registered models that
    reference them; no deployed module is unreferenced; every in-flight
    request's model is still registered (evict-during-serve safety)."""
    out = []
    if v.refcounts is not None and v.module_models is not None:
        for mod, refs in sorted(v.module_models.items()):
            claimed = v.refcounts.get(mod, 0)
            if claimed != len(refs):
                out.append(f"module {mod!r}: refcount {claimed} != "
                           f"{len(refs)} referencing model(s) {refs}")
    if v.refcounts is not None:
        for mod in v.deployed:
            if v.refcounts.get(mod, 0) < 1:
                out.append(f"module {mod!r} has live runtime but "
                           "refcount 0 (evict freed a served module)")
    if v.registered_models is not None:
        gone = [m for m in v.inflight_models
                if m not in v.registered_models]
        if gone:
            out.append(f"model(s) {gone} have in-flight requests but "
                       "were deregistered (evict during serve)")
    return out


@invariant("registry/decoder-pinned", layer="registry",
           checked_by=("model-check",))
def _decoder_pinned(v: StateView) -> list[str]:
    """A decoder module's host never changes while it has live
    sequences — its paged KV cache lives there (replan must not move
    it mid-stream)."""
    return [f"sequence {s.rid}'s decoder moved {s.host_at_admit} -> "
            f"{s.host} while live (paged cache left behind)"
            for s in v.live
            if s.host_at_admit is not None and s.host is not None
            and s.host != s.host_at_admit]


@invariant("sched/deadlock-free", layer="sched",
           checked_by=("model-check",))
def _deadlock_free(v: StateView) -> list[str]:
    """A state with pending work always has an enabled transition."""
    if v.enabled is None or v.terminal:
        return []
    if not v.enabled:
        pend = [w.rid for w in v.waiting] + [s.rid for s in v.live]
        return [f"deadlock: request(s) {pend} pending but no "
                "transition is enabled"]
    return []


@invariant("slo/bounded-inversion", layer="slo",
           checked_by=("model-check", "runtime"))
def _bounded_inversion(v: StateView) -> list[str]:
    """Admission never bypasses a waiting request with an earlier SLO
    deadline more than the configured bound allows."""
    if v.inversions > v.inversion_bound:
        return [f"{v.inversions} SLO priority inversion(s) "
                f"(bound {v.inversion_bound}): a later-deadline request "
                "was admitted past an earlier-deadline waiter"]
    return []
