"""Interprocedural lockset race detector for the serving stack.

``concurrency_lint`` is per-class and intra-procedural: it cannot see a
lock released across a method call, a helper that relies on every
caller holding the lock, or an admission/evict race that spans
``ServeScheduler`` -> ``DecodeStream`` -> ``PagePool``.  This module is
the Eraser-style upgrade:

1. **Call graph + type environment.**  All classes in the analyzed
   files share one namespace.  Attribute and local types are resolved
   from constructor calls (``self.pool = PagePool(...)``), annotations
   (``self.decode: dict[str, DecodeStream]`` — container element types
   included), parameter annotations, and simple aliasing
   (``stream = self.decode.get(m)``, ``for m, s in dict(self.decode)
   .items()``), so a call like ``stream.tick()`` resolves to
   ``DecodeStream.tick``.

2. **Lockset propagation.**  Starting from every *public* method of
   every lock-owning class with the empty lockset, the analysis walks
   the call graph, carrying the set of held locks — lock identity is
   ``(ClassName, lock_attr)`` — through calls, and records every
   ``self.X`` access (read and write) together with the lockset held at
   that program point.  Private helpers are analyzed only under the
   locksets their real callers establish, so a helper that is always
   entered with the lock held is *not* a false positive.

3. **Race report.**  For each shared attribute (written somewhere
   outside ``__init__``), if at least one access is guarded but the
   intersection of all access locksets is empty, the unprotected sites
   are reported: unguarded writes as ``locksets/unlocked-write``
   (ERROR), unguarded reads as ``locksets/unlocked-read`` (WARNING).
   Classes with *no* guarded access to an attribute are deliberately
   lock-free for it (``S2M3Engine``, ``PagePool`` rely on caller
   locking) and stay silent — callers are analyzed instead.

4. **Lock-order graph.**  Acquiring lock B while holding lock A adds
   edge A -> B (interprocedurally: the edge is found even when the
   acquisition happens two calls deep).  A cycle in this graph is a
   potential deadlock — ``locksets/lock-order-cycle`` (ERROR).

Suppression: a ``# lockset: ignore`` comment on the access line
silences that site.  Aliased mutation through locals
(``fl = self.inflight[r]; fl.pending.discard(...)``) remains invisible
— same documented blind spot as ``concurrency_lint``.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path

from repro.analysis.diagnostics import Diagnostic, Severity

_LOCK_CTORS = {"Lock", "RLock", "Condition", "Semaphore",
               "BoundedSemaphore"}
_MUTATORS = {"append", "appendleft", "extend", "extendleft", "insert",
             "pop", "popleft", "popitem", "remove", "discard", "clear",
             "update", "setdefault", "add", "release", "acquire_row",
             "track_max"}
_HEAP_FNS = {"heappush", "heappop", "heappushpop", "heapify"}
_PRAGMA = "lockset: ignore"


@dataclass(frozen=True, order=True)
class LockId:
    cls: str
    attr: str

    def __str__(self) -> str:
        return f"{self.cls}.{self.attr}"


@dataclass(frozen=True)
class _Type:
    """A resolved static type: a class, or a container of one."""

    cls: str
    container: bool = False     # dict/list/set of `cls` elements

    def element(self) -> "_Type | None":
        return _Type(self.cls) if self.container else None


@dataclass
class _Op:
    """One atomic fact collected from a method body, with the locks
    lexically held at that point (entry locks are added later)."""

    kind: str                   # "read" | "write" | "call" | "acquire"
    lineno: int
    locks: frozenset            # frozenset[LockId] held lexically
    attr: str = ""              # read/write: attribute name
    callee: tuple | None = None  # call: (class, method)
    lock: LockId | None = None  # acquire: the lock being taken


@dataclass
class _MethodInfo:
    name: str
    ops: list[_Op] = field(default_factory=list)


@dataclass
class _ClassInfo:
    name: str
    filename: str
    lock_attrs: set[str] = field(default_factory=set)
    methods: dict[str, _MethodInfo] = field(default_factory=dict)
    attr_types: dict[str, _Type] = field(default_factory=dict)
    node: ast.ClassDef | None = None


@dataclass(frozen=True)
class _AccessRec:
    cls: str
    attr: str
    method: str
    lineno: int
    filename: str
    kind: str                   # "read" | "write"
    locks: frozenset


# ---------------------------------------------------------------------------
# pass 1: class discovery, lock attrs, attribute types
# ---------------------------------------------------------------------------

def _annotation_type(node, known: set[str]) -> _Type | None:
    """``X`` / ``X | None`` / ``dict[K, X]`` / ``list[X]`` -> _Type."""
    if isinstance(node, ast.Name) and node.id in known:
        return _Type(node.id)
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return _Type(node.value) if node.value in known else None
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.BitOr):
        return (_annotation_type(node.left, known)
                or _annotation_type(node.right, known))
    if isinstance(node, ast.Subscript):
        base = node.value
        base_name = base.id if isinstance(base, ast.Name) else None
        elts = (node.slice.elts if isinstance(node.slice, ast.Tuple)
                else [node.slice])
        inner = _annotation_type(elts[-1], known)
        if inner is not None and base_name in {"dict", "list", "set",
                                               "Dict", "List", "Set",
                                               "deque", "Deque"}:
            return _Type(inner.cls, container=True)
    return None


def _self_attr(node) -> str | None:
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _ctor_type(node, known: set[str]) -> _Type | None:
    """``ClassName(...)`` -> _Type; ``dict(x)`` propagates x later."""
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in known):
        return _Type(node.func.id)
    return None


def _discover(trees: list[tuple[str, ast.Module]]) -> dict[str, _ClassInfo]:
    classes: dict[str, _ClassInfo] = {}
    for filename, tree in trees:
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef):
                classes[node.name] = _ClassInfo(node.name, filename,
                                                node=node)
    known = set(classes)
    for info in classes.values():
        cls = info.node
        for m in [n for n in cls.body
                  if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
            params = {a.arg: _annotation_type(a.annotation, known)
                      for a in m.args.args if a.annotation is not None}
            for node in ast.walk(m):
                if isinstance(node, ast.Assign):
                    for t in node.targets:
                        a = _self_attr(t)
                        if a is None:
                            continue
                        v = node.value
                        ctor = (v.func if isinstance(v, ast.Call) else None)
                        cname = (ctor.attr if isinstance(ctor, ast.Attribute)
                                 else ctor.id if isinstance(ctor, ast.Name)
                                 else None)
                        if cname in _LOCK_CTORS:
                            info.lock_attrs.add(a)
                            continue
                        ty = _ctor_type(v, known)
                        if ty is None and isinstance(v, ast.Name):
                            ty = params.get(v.id)      # self.x = param
                        if ty is not None:
                            info.attr_types.setdefault(a, ty)
                elif isinstance(node, ast.AnnAssign):
                    a = _self_attr(node.target)
                    if a is not None:
                        ty = _annotation_type(node.annotation, known)
                        if ty is not None:
                            info.attr_types.setdefault(a, ty)
                elif isinstance(node, (ast.With, ast.AsyncWith)):
                    for item in node.items:
                        a = _self_attr(item.context_expr)
                        if a is not None and "lock" in a.lower():
                            info.lock_attrs.add(a)
    return classes


# ---------------------------------------------------------------------------
# pass 2: per-method op collection (lexical locks + local types)
# ---------------------------------------------------------------------------

class _Collector:
    def __init__(self, info: _ClassInfo, classes: dict[str, _ClassInfo]):
        self.info = info
        self.classes = classes
        self.known = set(classes)

    def collect(self, m: ast.FunctionDef) -> _MethodInfo:
        out = _MethodInfo(m.name)
        types: dict[str, _Type] = {}
        for a in m.args.args:
            ty = _annotation_type(a.annotation, self.known)
            if ty is not None:
                types[a.arg] = ty
        self._block(m.body, frozenset(), types, out)
        return out

    # -- type resolution ------------------------------------------------
    def _expr_type(self, node, types) -> _Type | None:
        if isinstance(node, ast.Name):
            return types.get(node.id)
        a = _self_attr(node)
        if a is not None:
            return self.info.attr_types.get(a)
        if isinstance(node, ast.Subscript):
            t = self._expr_type(node.value, types)
            return t.element() if t is not None else None
        if isinstance(node, ast.Call):
            ty = _ctor_type(node, self.known)
            if ty is not None:
                return ty
            fn = node.func
            # dict(self.decode) / list(...) keep the element type
            if (isinstance(fn, ast.Name) and fn.id in {"dict", "list",
                                                       "sorted", "set"}
                    and node.args):
                return self._expr_type(node.args[0], types)
            # self.decode.get(k) / .setdefault(k, v) / .pop(k) -> element
            if (isinstance(fn, ast.Attribute)
                    and fn.attr in {"get", "setdefault", "pop"}):
                t = self._expr_type(fn.value, types)
                return t.element() if t is not None else None
            if isinstance(fn, ast.Attribute) and fn.attr in {"items",
                                                             "values"}:
                return self._expr_type(fn.value, types)
        return None

    def _bind(self, target, value_type, types) -> None:
        if value_type is None:
            return
        if isinstance(target, ast.Name):
            types[target.id] = value_type
        elif (isinstance(target, ast.Tuple)
              and value_type.container is False and len(target.elts) == 2):
            # for k, v in <dict-of-X>.items(): bind v
            if isinstance(target.elts[1], ast.Name):
                types[target.elts[1].id] = value_type

    # -- op emission ----------------------------------------------------
    def _lock_of(self, node, types) -> LockId | None:
        """``self._lock`` / ``<typed>.lockattr`` -> LockId."""
        a = _self_attr(node)
        if a is not None:
            if a in self.info.lock_attrs or "lock" in a.lower():
                return LockId(self.info.name, a)
            return None
        if isinstance(node, ast.Attribute) and "lock" in node.attr.lower():
            t = self._expr_type(node.value, types)
            if t is not None and not t.container:
                return LockId(t.cls, node.attr)
        return None

    def _resolve_call(self, call: ast.Call, types) -> tuple | None:
        fn = call.func
        if not isinstance(fn, ast.Attribute):
            return None
        a = _self_attr(fn)
        if a is not None:
            # self.m() — a self-call when m is a method of this class
            if any(isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                   and n.name == a for n in self.info.node.body):
                return (self.info.name, a)
            return None
        t = self._expr_type(fn.value, types)
        if t is None or t.container:
            return None
        target = self.classes.get(t.cls)
        if target is not None and fn.attr in {
                n.name for n in target.node.body
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}:
            return (t.cls, fn.attr)
        return None

    def _scan_expr(self, node, locks, types, out: _MethodInfo) -> None:
        """Record reads, mutator-call writes, and resolved calls inside
        one expression."""
        skip: set[int] = set()
        for call in ast.walk(node):
            if not isinstance(call, ast.Call):
                continue
            callee = self._resolve_call(call, types)
            if callee is not None:
                out.ops.append(_Op("call", call.lineno, locks,
                                   callee=callee))
                if callee[0] == self.info.name:
                    skip.add(id(call.func))   # self.m is not a state read
            fn = call.func
            if isinstance(fn, ast.Attribute) and fn.attr in _MUTATORS:
                a = _self_attr(fn.value)
                if a is not None and a not in self.info.lock_attrs:
                    out.ops.append(_Op("write", call.lineno, locks, attr=a))
                    skip.add(id(fn.value))
            # heapq.heappush(self.waiting, ...) mutates its first arg
            hname = (fn.attr if isinstance(fn, ast.Attribute)
                     else fn.id if isinstance(fn, ast.Name) else None)
            if hname in _HEAP_FNS and call.args:
                a = _self_attr(call.args[0])
                if a is not None:
                    out.ops.append(_Op("write", call.lineno, locks, attr=a))
                    skip.add(id(call.args[0]))
        for sub in ast.walk(node):
            a = _self_attr(sub)
            if (a is None or id(sub) in skip
                    or a in self.info.lock_attrs
                    or not isinstance(sub.ctx, ast.Load)):
                continue
            out.ops.append(_Op("read", sub.lineno, locks, attr=a))

    def _write_targets(self, stmt, locks, types, out: _MethodInfo) -> None:
        targets = []
        if isinstance(stmt, ast.Assign):
            targets = stmt.targets
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            targets = [stmt.target]
        elif isinstance(stmt, ast.Delete):
            targets = stmt.targets
        for t in targets:
            a = _self_attr(t)
            if a is not None and a not in self.info.lock_attrs:
                out.ops.append(_Op("write", stmt.lineno, locks, attr=a))
            if isinstance(t, ast.Subscript):
                a = _self_attr(t.value)
                if a is not None and a not in self.info.lock_attrs:
                    out.ops.append(_Op("write", stmt.lineno, locks, attr=a))
                else:
                    self._scan_expr(t.value, locks, types, out)
                self._scan_expr(t.slice, locks, types, out)

    def _block(self, stmts, locks: frozenset, types: dict,
               out: _MethodInfo) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                inner = locks
                for item in stmt.items:
                    lid = self._lock_of(item.context_expr, types)
                    if lid is not None:
                        out.ops.append(_Op("acquire", stmt.lineno, inner,
                                           lock=lid))
                        inner = inner | {lid}
                    else:
                        self._scan_expr(item.context_expr, locks, types,
                                        out)
                self._block(stmt.body, inner, types, out)
            elif isinstance(stmt, (ast.If, ast.While)):
                self._scan_expr(stmt.test, locks, types, out)
                self._block(stmt.body, locks, types, out)
                self._block(stmt.orelse, locks, types, out)
            elif isinstance(stmt, (ast.For, ast.AsyncFor)):
                self._scan_expr(stmt.iter, locks, types, out)
                self._bind(stmt.target,
                           self._expr_type(stmt.iter, types), types)
                self._block(stmt.body, locks, types, out)
                self._block(stmt.orelse, locks, types, out)
            elif isinstance(stmt, ast.Try):
                self._block(stmt.body, locks, types, out)
                for h in stmt.handlers:
                    self._block(h.body, locks, types, out)
                self._block(stmt.orelse, locks, types, out)
                self._block(stmt.finalbody, locks, types, out)
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._block(stmt.body, locks, types, out)
            elif isinstance(stmt, ast.Return):
                if stmt.value is not None:
                    self._scan_expr(stmt.value, locks, types, out)
            else:
                self._write_targets(stmt, locks, types, out)
                if isinstance(stmt, ast.Assign):
                    self._scan_expr(stmt.value, locks, types, out)
                    ty = self._expr_type(stmt.value, types)
                    for t in stmt.targets:
                        self._bind(t, ty, types)
                elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                    if getattr(stmt, "value", None) is not None:
                        self._scan_expr(stmt.value, locks, types, out)
                    if isinstance(stmt, ast.AugAssign):
                        # x += 1 reads x too
                        a = _self_attr(stmt.target)
                        if a is not None:
                            out.ops.append(_Op("read", stmt.lineno, locks,
                                               attr=a))
                elif isinstance(stmt, ast.Expr):
                    self._scan_expr(stmt.value, locks, types, out)
                elif isinstance(stmt, (ast.Assert, ast.Raise)):
                    for v in ast.walk(stmt):
                        if v is not stmt:
                            pass
                    self._scan_expr(stmt, locks, types, out)


# ---------------------------------------------------------------------------
# pass 3: interprocedural fixpoint
# ---------------------------------------------------------------------------

@dataclass
class LocksetReport:
    diagnostics: list[Diagnostic]
    contexts: int                  # (class, method, entry-lockset) analyzed
    accesses: int                  # shared-attribute accesses recorded
    lock_edges: list[tuple[LockId, LockId, int]]

    def errors(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics
                if d.severity >= Severity.ERROR]


def _analyze(classes: dict[str, _ClassInfo],
             sources: dict[str, list[str]]) -> LocksetReport:
    for info in classes.values():
        coll = _Collector(info, classes)
        for n in info.node.body:
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                info.methods[n.name] = coll.collect(n)

    records: list[_AccessRec] = []
    edges: dict[tuple[LockId, LockId], int] = {}
    seen: set[tuple[str, str, frozenset]] = set()
    work: list[tuple[str, str, frozenset]] = []

    # entry points: public methods of lock-owning classes run with no
    # lock held; lock-free classes (engine, allocators) are analyzed
    # only under the locksets their callers establish
    for cname, info in classes.items():
        if not info.lock_attrs:
            continue
        for mname in info.methods:
            if mname == "__init__" or mname.startswith("__"):
                continue
            if not mname.startswith("_"):
                work.append((cname, mname, frozenset()))
    seen.update(work)

    while work:
        cname, mname, entry = work.pop()
        info = classes[cname]
        method = info.methods.get(mname)
        if method is None or mname == "__init__":
            continue
        for op in method.ops:
            eff = entry | op.locks
            if op.kind in ("read", "write"):
                records.append(_AccessRec(cname, op.attr, mname, op.lineno,
                                          info.filename, op.kind,
                                          frozenset(eff)))
            elif op.kind == "call":
                key = (op.callee[0], op.callee[1], frozenset(eff))
                if key not in seen:
                    seen.add(key)
                    work.append(key)
            elif op.kind == "acquire":
                for held in eff:
                    if held != op.lock:
                        edges.setdefault((held, op.lock), op.lineno)

    diags = _report(classes, records, sources)
    diags += _cycles(edges, classes)
    edge_list = [(a, b, ln) for (a, b), ln in sorted(
        edges.items(), key=lambda kv: (str(kv[0][0]), str(kv[0][1])))]
    return LocksetReport(diags, contexts=len(seen), accesses=len(records),
                         lock_edges=edge_list)


def _suppressed(rec: _AccessRec, sources) -> bool:
    lines = sources.get(rec.filename, ())
    if 0 < rec.lineno <= len(lines):
        return _PRAGMA in lines[rec.lineno - 1]
    return False


def _report(classes, records: list[_AccessRec], sources) -> list[Diagnostic]:
    by_attr: dict[tuple[str, str], list[_AccessRec]] = {}
    for r in records:
        by_attr.setdefault((r.cls, r.attr), []).append(r)

    diags: list[Diagnostic] = []
    for (cname, attr), recs in sorted(by_attr.items()):
        if not any(r.kind == "write" for r in recs):
            continue                     # never mutated: safe to share
        guarded = [r for r in recs if r.locks]
        if not guarded:
            continue                     # deliberately lock-free
        common = frozenset.intersection(*[r.locks for r in recs])
        if common:
            continue                     # consistently guarded
        consensus = frozenset.intersection(*[r.locks for r in guarded])
        if not consensus:
            sample = guarded[0]
            diags.append(Diagnostic(
                Severity.ERROR, "locksets/inconsistent-locks",
                f"{cname}.{attr} is guarded by different locks at "
                f"different sites ({sorted({str(l) for r in guarded for l in r.locks})}); "
                "no single lock protects it",
                entity=f"{sample.filename}:{sample.lineno}",
                hint="pick one lock and hold it at every access"))
            continue
        reported: set[tuple[int, str]] = set()
        for r in recs:
            if r.locks & consensus or _suppressed(r, sources):
                continue
            key = (r.lineno, r.kind)
            if key in reported:
                continue
            reported.add(key)
            lockstr = " + ".join(sorted(str(l) for l in consensus))
            if r.kind == "write":
                diags.append(Diagnostic(
                    Severity.ERROR, "locksets/unlocked-write",
                    f"{cname}.{r.method} writes self.{attr} with no lock "
                    f"held, but other sites guard it with {lockstr}; "
                    "concurrent submit/drain threads race here",
                    entity=f"{r.filename}:{r.lineno}",
                    hint=f"hold {lockstr} across the write (the lockset "
                         "is propagated through calls — acquiring in a "
                         "caller also fixes this)"))
            else:
                diags.append(Diagnostic(
                    Severity.WARNING, "locksets/unlocked-read",
                    f"{cname}.{r.method} reads self.{attr} with no lock "
                    f"held while writers guard it with {lockstr}; the "
                    "read can observe a torn or stale value",
                    entity=f"{r.filename}:{r.lineno}",
                    hint=f"snapshot self.{attr} under {lockstr} and use "
                         "the copy"))
    return diags


def _cycles(edges: dict[tuple[LockId, LockId], int],
            classes) -> list[Diagnostic]:
    graph: dict[LockId, set[LockId]] = {}
    for a, b in edges:
        graph.setdefault(a, set()).add(b)
    diags: list[Diagnostic] = []
    seen_cycles: set[frozenset] = set()

    def dfs(start: LockId, node: LockId, path: list[LockId]):
        for nxt in sorted(graph.get(node, ()), key=str):
            if nxt == start and len(path) > 1:
                key = frozenset(path)
                if key not in seen_cycles:
                    seen_cycles.add(key)
                    cyc = " -> ".join(str(l) for l in path + [start])
                    ln = edges.get((path[-1], start), 0)
                    fn = classes[path[-1].cls].filename
                    diags.append(Diagnostic(
                        Severity.ERROR, "locksets/lock-order-cycle",
                        f"lock-order cycle: {cyc}; two threads entering "
                        "from opposite ends deadlock",
                        entity=f"{fn}:{ln}",
                        hint="impose a global acquisition order or "
                             "release the first lock before taking the "
                             "second"))
            elif nxt not in path:
                dfs(start, nxt, path + [nxt])

    for node in sorted(graph, key=str):
        dfs(node, node, [node])
    return diags


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def analyze_sources(named_sources: list[tuple[str, str]]) -> LocksetReport:
    """Analyze ``(filename, source)`` pairs as one shared namespace."""
    trees = []
    sources: dict[str, list[str]] = {}
    diags: list[Diagnostic] = []
    for filename, src in named_sources:
        sources[filename] = src.splitlines()
        try:
            trees.append((filename, ast.parse(src, filename=filename)))
        except SyntaxError as e:
            diags.append(Diagnostic(
                Severity.ERROR, "locksets/syntax-error",
                f"cannot parse {filename}: {e}", entity=filename))
    classes = _discover(trees)
    report = _analyze(classes, sources)
    report.diagnostics = diags + report.diagnostics
    return report


def analyze_paths(paths) -> LocksetReport:
    named = []
    for p in paths:
        p = Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        named += [(str(f), f.read_text()) for f in files]
    return analyze_sources(named)


def lint_serving_locksets() -> LocksetReport:
    """Run the detector over the in-tree serving layer — scheduler,
    decode streams, allocators, and engine analyzed as one call graph."""
    import repro.serving as serving

    root = Path(serving.__file__).parent
    files = [root / f for f in ("scheduler.py", "decode.py",
                                "kvcache.py", "engine.py")]
    return analyze_paths([f for f in files if f.exists()])


# ---------------------------------------------------------------------------
# seeded-mutation self-test
# ---------------------------------------------------------------------------

class _LockStripper(ast.NodeTransformer):
    """Remove ``with self.<lock>:`` wrappers inside one method — the
    'removed lock acquisition' seeded bug, applied to the *real* source."""

    def __init__(self, cls: str, method: str):
        self.cls = cls
        self.method = method
        self._in_target = False
        self.stripped = 0

    def visit_ClassDef(self, node):
        if node.name != self.cls:
            return node
        self.generic_visit(node)
        return node

    def visit_FunctionDef(self, node):
        if node.name != self.method:
            return node
        self._in_target = True
        self.generic_visit(node)
        self._in_target = False
        return node

    def visit_With(self, node):
        self.generic_visit(node)
        if not self._in_target:
            return node
        for item in node.items:
            a = _self_attr(item.context_expr)
            if a is not None and "lock" in a.lower():
                self.stripped += 1
                return node.body          # splice the body in, lock gone
        return node


def strip_lock(src: str, cls: str, method: str) -> str:
    """Return ``src`` with every ``with self._lock:`` removed from
    ``cls.method`` (raises if none was found — the mutation must bite)."""
    tree = ast.parse(src)
    stripper = _LockStripper(cls, method)
    tree = ast.fix_missing_locations(stripper.visit(tree))
    if not stripper.stripped:
        raise ValueError(f"no lock acquisition found in {cls}.{method}")
    return ast.unparse(tree)


_DEADLOCK_SNIPPET = '''
import threading

class Left:
    def __init__(self, peer: "Right"):
        self._lock = threading.Lock()
        self.peer = peer
        self.count = 0

    def bump(self):
        with self._lock:
            self.count += 1
            self.peer.poke()      # acquires Right._lock under Left._lock

class Right:
    def __init__(self, peer: "Left"):
        self._lock = threading.Lock()
        self.peer = peer
        self.count = 0

    def poke(self):
        with self._lock:
            self.count += 1

    def bump(self):
        with self._lock:
            self.count += 1
            self.peer.bump()      # acquires Left._lock under Right._lock
'''


def self_test() -> list[Diagnostic]:
    """Prove the detector catches seeded concurrency bugs and stays
    silent on the real serving tree."""
    import repro.serving as serving

    diags: list[Diagnostic] = []
    root = Path(serving.__file__).parent

    # 1. the real tree must be lockset-clean
    base = lint_serving_locksets()
    if base.diagnostics:
        worst = base.diagnostics[0]
        diags.append(Diagnostic(
            Severity.ERROR, "locksets/unclean-baseline",
            f"serving tree has {len(base.diagnostics)} lockset finding(s); "
            f"first: {worst.message}", entity=worst.entity,
            hint="fix the race (or annotate `# lockset: ignore` with a "
                 "justification) before trusting the self-test"))
    else:
        diags.append(Diagnostic(
            Severity.INFO, "locksets/clean",
            f"serving tree lockset-clean: {base.contexts} contexts, "
            f"{base.accesses} accesses, {len(base.lock_edges)} lock-order "
            "edge(s), no cycle", entity=str(root)))

    # 2. removed lock acquisition in the real DecodeStream.submit must
    # surface as an unlocked write racing the locked admission path
    decode_src = (root / "decode.py").read_text()
    mutated = strip_lock(decode_src, "DecodeStream", "submit")
    rep = analyze_sources([("decode.py<removed-lock>", mutated)])
    hit = [d for d in rep.diagnostics
           if d.code in ("locksets/unlocked-write", "locksets/unlocked-read")
           and ".submit " in d.message]
    if hit:
        diags.append(Diagnostic(
            Severity.INFO, "locksets/mutation-caught",
            "seeded bug 'removed-lock' (DecodeStream.submit without "
            f"self._lock) caught: {hit[0].message}", entity="removed-lock"))
    else:
        diags.append(Diagnostic(
            Severity.ERROR, "locksets/mutation-missed",
            "stripping the lock from DecodeStream.submit produced no "
            "unlocked-access finding", entity="removed-lock",
            hint="interprocedural lockset propagation lost coverage"))

    # 3. an inverted cross-class acquisition order must be reported as a
    # lock-order cycle
    rep = analyze_sources([("deadlock.py<lock-order>", _DEADLOCK_SNIPPET)])
    cyc = [d for d in rep.diagnostics
           if d.code == "locksets/lock-order-cycle"]
    if cyc:
        diags.append(Diagnostic(
            Severity.INFO, "locksets/mutation-caught",
            f"seeded bug 'lock-order-cycle' caught: {cyc[0].message}",
            entity="lock-order-cycle"))
    else:
        diags.append(Diagnostic(
            Severity.ERROR, "locksets/mutation-missed",
            "inverted lock order in the seeded two-class snippet was not "
            "reported as a cycle", entity="lock-order-cycle",
            hint="lock-order edge propagation lost coverage"))
    return diags
