"""repro.analysis — static analysis for S2M3 deployments.

Three passes, all device-free, all returning structured ``Diagnostic``
objects (severity, stable code, anchoring entity, fix hint):

* **plan verifier** (``plan_check``) — per-device memory ledgers vs
  capacity, module→host mapping completeness, dependency-graph
  acyclicity, route reachability, registry refcount consistency, and
  sharing legality (shared encoders must agree on shape/dtype fields);
* **kernel checker** (``kernel_check``) — abstract-evals the Pallas
  kernels (``jax.eval_shape``, no device execution) for the zoo's real
  shapes: grid/BlockSpec divisibility, per-block VMEM footprint vs a
  configurable budget, output shape/dtype drift vs ``kernels/ref.py``;
* **concurrency lint** (``concurrency_lint``) — AST pass over the
  serving layer: shared-state mutation outside the scheduler lock, JAX
  dispatch while holding the lock, registry mutation from
  batch-coalescing paths.

Severities (``Severity``): **ERROR** means executing the plan would
fail (OOM, KeyError, race) — ``Deployment`` pre-flights raise
``PlanError`` and the CLI exits non-zero; **WARNING** means
likely-wrong but executable (VMEM over budget, ignored plan option) —
pre-flights log these; **INFO** is an observation (kernel grid/VMEM
summaries).

Entry points: ``Deployment.verify()`` (and the automatic pre-flight in
``materialize()``/``serve()``), or the CLI::

    python -m repro.analysis --self         # lint this repo, kernel-check
                                            # the zoo; exit 1 on ERROR
    python -m repro.analysis path/to/file.py --kernels

``--self`` is the CI/tier-1 mode: it lints the installed ``repro``
package sources and sweeps every kernel entry point over the zoo's
shapes.
"""

from __future__ import annotations

from repro.analysis.diagnostics import (
    Diagnostic, PlanError, Severity, errors, format_report, warnings,
)

__all__ = [
    "Diagnostic", "PlanError", "Severity", "errors", "format_report",
    "warnings", "verify_deployment",
]


def verify_deployment(dep, *, kernels: bool = False,
                      vmem_budget: int | None = None,
                      decode_pages: int | None = None,
                      page_size: int | None = None,
                      model_check: bool = False,
                      mc_budget: float = 10.0) -> list[Diagnostic]:
    """Run the static plan verifier (and optionally the kernel checker
    and schedule-space model checker) against a ``s2m3.Deployment``.
    When ``decode_pages``/``page_size`` are given (the serve()
    pre-flight passes the scheduler's actual knobs), generative heads'
    paged-KV pools are checked against the per-device memory ledgers
    too.  ``model_check=True`` exhaustively explores bounded request
    interleavings of a scenario derived from this deployment's models
    (``modelcheck.scenario_from_deployment``), evaluating the invariant
    catalog at every state; a counterexample becomes an ERROR carrying
    the replayable transition script.  Pure inspection: raises nothing,
    returns the finding list for the caller's policy."""
    from repro.analysis.plan_check import check_page_budget, check_plan

    placement = dep._ensure_plan()
    diags = check_plan(
        placement, dep.cluster, dep.models, registry=dep.registry,
        placement_name=dep._placement_name, plan_opts=dep._plan_opts)
    if decode_pages is not None and page_size is not None:
        diags = diags + check_page_budget(
            placement, dep.cluster, dep.models,
            decode_pages=decode_pages, page_size=page_size)
    if kernels:
        from repro.analysis.kernel_check import check_kernels

        diags = diags + check_kernels(vmem_budget=vmem_budget)
    if model_check:
        diags = diags + model_check_deployment(dep, budget_s=mc_budget)
    return diags


def model_check_deployment(dep, *, budget_s: float = 10.0
                           ) -> list[Diagnostic]:
    """Model-check a scenario derived from ``dep``'s registered models
    under a wall-clock budget; one Diagnostic summarising the run, plus
    an ERROR per invariant counterexample (with transition script)."""
    from repro.analysis import modelcheck as mc

    cfg = mc.scenario_from_deployment(dep)
    res = mc.check(cfg, budget_s=budget_s)
    if res.counterexample is not None:
        cx = res.counterexample
        return [Diagnostic(
            Severity.ERROR, f"modelcheck/{cx.invariant}",
            f"schedule-space violation of {cx.invariant}: {cx.message}\n"
            f"counterexample ({len(cx.script)} step(s)):\n"
            + cx.format_script(),
            entity="Deployment",
            hint="replay with repro.analysis.modelcheck.replay(); export "
                 "a Chrome trace via Counterexample.save_trace()")]
    sev = Severity.INFO if res.complete else Severity.WARNING
    note = ("" if res.complete else
            " (exploration truncated by budget — not exhaustive)")
    return [Diagnostic(
        sev, "modelcheck/clean" if res.complete else "modelcheck/truncated",
        f"schedule-space model check: {res.summary()}{note}",
        entity="Deployment")]
