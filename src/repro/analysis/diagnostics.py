"""Structured diagnostics shared by every analysis pass.

A ``Diagnostic`` is one finding: severity, a stable ``code`` (grep /
suppress key, e.g. ``plan/memory-overflow``), a human message, the plan
entity or source location it anchors to, and a fix hint.  Passes return
lists of these; callers decide policy (``Deployment`` pre-flights raise
on ERROR and log WARNINGs, the CLI exits non-zero on ERROR).

Kept dependency-free (stdlib only) so low-level modules — the serving
engine, the kernels — can raise ``PlanError`` without importing the
heavier checker passes.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field


class Severity(enum.IntEnum):
    """Finding severity.  Ordering is meaningful: higher is worse.

    * ``ERROR``   — the plan/kernel/code is unsound; executing it would
      fail (OOM, KeyError, race).  Pre-flights raise, CI fails.
    * ``WARNING`` — likely-wrong or wasteful, but executable (VMEM
      estimate over budget, unknown plan option, stale ledger entry).
    * ``INFO``    — observations (e.g. sharing savings summary).
    """

    INFO = 10
    WARNING = 20
    ERROR = 30

    def __str__(self) -> str:  # "ERROR", not "Severity.ERROR"
        return self.name


@dataclass(frozen=True)
class Diagnostic:
    severity: Severity
    code: str                    # "<pass>/<rule>", stable across releases
    message: str
    entity: str | None = None    # plan entity (module/device) or "file:line"
    hint: str | None = None      # concrete fix suggestion

    def format(self) -> str:
        loc = f" [{self.entity}]" if self.entity else ""
        tail = f"  (fix: {self.hint})" if self.hint else ""
        return f"{self.severity} {self.code}{loc}: {self.message}{tail}"


def errors(diags: list[Diagnostic]) -> list[Diagnostic]:
    return [d for d in diags if d.severity >= Severity.ERROR]


def warnings(diags: list[Diagnostic]) -> list[Diagnostic]:
    return [d for d in diags if d.severity == Severity.WARNING]


def format_report(diags: list[Diagnostic]) -> str:
    if not diags:
        return "no findings"
    lines = [d.format() for d in
             sorted(diags, key=lambda d: (-d.severity, d.code))]
    n_err, n_warn = len(errors(diags)), len(warnings(diags))
    lines.append(f"{len(diags)} finding(s): {n_err} error(s), "
                 f"{n_warn} warning(s)")
    return "\n".join(lines)


@dataclass
class PlanError(KeyError):
    """A plan is statically unsound (or was caught being unsound at
    runtime — ``engine.module_hosts``).  Subclasses ``KeyError`` because
    that is what the engine's mapping lookups historically raised;
    existing ``except KeyError`` call sites keep working.

    ``diagnostics`` carries the full finding list when raised by a
    ``Deployment.verify()`` pre-flight; the module/requested/available
    fields are set when raised for a single unmapped module.
    """

    message: str
    module: str | None = None
    requested: tuple[str, ...] = ()
    available: tuple[str, ...] = ()
    diagnostics: list[Diagnostic] = field(default_factory=list)

    def __post_init__(self):
        KeyError.__init__(self, self.message)

    def __str__(self) -> str:    # KeyError repr-quotes its arg; don't
        return self.message
