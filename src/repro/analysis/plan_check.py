"""Static plan verifier: prove a placement sound before it touches a
device.

Given a ``Placement`` + ``ClusterSpec`` + the model set (and optionally
the ``ModuleRegistry`` and the pinned plan options), emits structured
``Diagnostic``s for every way the plan could fail at runtime:

* ``plan/memory-overflow``     — a device's memory ledger exceeds its
  capacity (the mid-``serve()`` OOM, caught statically).
* ``plan/infeasible``          — the strategy itself gave up on a module.
* ``plan/unmapped-module``     — a model references a module the plan
  never assigned (front-runs the engine's ``module_hosts`` PlanError).
* ``plan/unknown-device``      — an assignment names a device that is
  not in the cluster.
* ``plan/duplicate-replica``   — the same device listed twice for one
  module (double-charged ledger).
* ``plan/signature-collision`` — sharing legality: two tasks reuse one
  module signature with different shape/dtype-bearing specs.
* ``plan/dependency-cycle``    — the module dependency graph
  (encoder -> head edges across all models) is not a DAG.
* ``plan/unreachable-route``   — an encoder's host cannot ship its
  output to any of the head's hosts (explicit zero-bandwidth link).
* ``plan/refcount-mismatch``   — registry refcounts disagree with the
  placement (module referenced by live models but not placed).
* ``plan/stale-assignment``    — placement carries a module no live
  model references (eviction leftovers).
* ``plan/unknown-option``      — a plan kwarg the pinned strategy does
  not accept (typo catcher; strategies swallow unknown ``**_``).
* ``plan/page-budget``         — a generative head's paged-KV pool
  (``decode_pages * page_size * kv_bytes_per_token``) does not fit next
  to the weights already on its host (``check_page_budget``, run by the
  ``serve()`` pre-flight with the scheduler's actual decode knobs).
* ``plan/kv-unspecified``      — a generative head declares no
  ``kv_bytes_per_token``, so its page pool cannot be budgeted.
"""

from __future__ import annotations

from repro.analysis.diagnostics import Diagnostic, Severity
from repro.core.cluster import ClusterSpec
from repro.core.module import ModelSpec, ModuleSpec
from repro.core.placement import Placement

_MB = 1024 ** 2

# spec fields that determine whether two tasks may legally share one
# deployed module: architecture size, deployed dtype, and the I/O
# contract (payload in, embedding out)
_SHARING_FIELDS = ("kind", "modality", "n_params", "bytes_per_param",
                   "input_bytes", "output_bytes")


def _hosts_for(placement: Placement, module: ModuleSpec,
               model: ModelSpec) -> list[str]:
    """Assignment lookup that understands both shared keys and the
    no-share strategy's model-suffixed keys."""
    hosts = placement.assignment.get(module.name)
    if hosts is None:
        hosts = placement.assignment.get(f"{module.name}::{model.name}")
    return list(hosts or ())


def check_plan(
    placement: Placement,
    cluster: ClusterSpec,
    models: list[ModelSpec],
    *,
    registry=None,                       # core.registry.ModuleRegistry | None
    placement_name: str | None = None,
    plan_opts: dict | None = None,
) -> list[Diagnostic]:
    diags: list[Diagnostic] = []
    dev_names = {d.name for d in cluster.devices}
    module_specs: dict[str, ModuleSpec] = {}
    for mdl in models:
        for m in mdl.modules:
            module_specs.setdefault(m.name, m)

    # -- strategy gave up -----------------------------------------------
    if not placement.feasible:
        for name in (placement.infeasible_modules or ["<plan>"]):
            diags.append(Diagnostic(
                Severity.ERROR, "plan/infeasible",
                f"placement strategy found no device with room for "
                f"{name!r}", entity=name,
                hint="add capacity, evict a model, or drop replication"))

    # -- sharing legality ------------------------------------------------
    diags += _check_sharing(models)

    # -- mapping completeness + host validity ----------------------------
    for mdl in models:
        for m in mdl.modules:
            hosts = _hosts_for(placement, m, mdl)
            if not hosts:
                if m.name in placement.infeasible_modules:
                    continue             # already reported as infeasible
                diags.append(Diagnostic(
                    Severity.ERROR, "plan/unmapped-module",
                    f"module {m.name!r} of model {mdl.name!r} has no "
                    f"host in the plan (assigned modules: "
                    f"{sorted(placement.assignment)})", entity=m.name,
                    hint="re-run plan() after admitting the model, or "
                         "extend the cluster"))
            seen: set[str] = set()
            for h in hosts:
                if h not in dev_names:
                    diags.append(Diagnostic(
                        Severity.ERROR, "plan/unknown-device",
                        f"module {m.name!r} is assigned to {h!r}, which "
                        f"is not in the cluster "
                        f"(devices: {sorted(dev_names)})", entity=h,
                        hint="replan() against the current cluster"))
                if h in seen:
                    diags.append(Diagnostic(
                        Severity.WARNING, "plan/duplicate-replica",
                        f"device {h!r} listed twice for module "
                        f"{m.name!r}; the ledger double-charges it",
                        entity=m.name))
                seen.add(h)

    # -- per-device memory ledger ----------------------------------------
    bytes_of = dict(placement.module_bytes)
    for key in placement.assignment:
        if key not in bytes_of:
            base = key.split("::", 1)[0]
            spec = module_specs.get(base)
            bytes_of[key] = spec.mem_bytes if spec else 0
    for dev in cluster.devices:
        used = placement.bytes_used_on(dev.name, bytes_of)
        if used > dev.mem_capacity:
            diags.append(Diagnostic(
                Severity.ERROR, "plan/memory-overflow",
                f"device {dev.name!r} ledger {used / _MB:.1f} MB exceeds "
                f"capacity {dev.mem_capacity / _MB:.1f} MB "
                f"(modules: {sorted(placement.modules_on(dev.name))})",
                entity=dev.name,
                hint="move or shrink a module, or drop a replica"))

    # -- dependency-graph acyclicity -------------------------------------
    diags += _check_acyclic(models)

    # -- route reachability ----------------------------------------------
    diags += _check_reachable(placement, cluster, models, dev_names)

    # -- registry refcount consistency -----------------------------------
    if registry is not None:
        diags += _check_refcounts(placement, registry, models)

    # -- plan-option typos -----------------------------------------------
    if placement_name and plan_opts:
        diags += _check_plan_opts(placement_name, plan_opts)

    return diags


def check_page_budget(
    placement: Placement,
    cluster: ClusterSpec,
    models: list[ModelSpec],
    *,
    decode_pages: int,
    page_size: int,
) -> list[Diagnostic]:
    """Paged-KV memory ledger for generative heads: each head's decode
    stream allocates ``decode_pages`` pages of ``page_size`` tokens, at
    ``ModuleSpec.kv_bytes_per_token`` bytes per token, resident on the
    head's host next to every module weight already placed there."""
    diags: list[Diagnostic] = []
    heads: dict[str, ModuleSpec] = {}
    for mdl in models:
        if mdl.head.generative:
            heads.setdefault(mdl.head.name, mdl.head)
    if not heads:
        return diags

    bytes_of = dict(placement.module_bytes)
    module_specs = {m.name: m for mdl in models for m in mdl.modules}
    for key in placement.assignment:
        if key not in bytes_of:
            spec = module_specs.get(key.split("::", 1)[0])
            bytes_of[key] = spec.mem_bytes if spec else 0
    cap = {d.name: d.mem_capacity for d in cluster.devices}

    for name, head in sorted(heads.items()):
        if head.kv_bytes_per_token <= 0:
            diags.append(Diagnostic(
                Severity.WARNING, "plan/kv-unspecified",
                f"generative head {name!r} declares no kv_bytes_per_token; "
                "its page pool cannot be checked against device memory",
                entity=name,
                hint="set ModuleSpec.kv_bytes_per_token = "
                     "2 * n_layers * n_kv_heads * head_dim * bytes/elt"))
            continue
        pool = decode_pages * page_size * head.kv_bytes_per_token
        for host in placement.assignment.get(name, ()):
            if host not in cap:
                continue                 # plan/unknown-device covers it
            used = placement.bytes_used_on(host, bytes_of)
            if used + pool > cap[host]:
                diags.append(Diagnostic(
                    Severity.ERROR, "plan/page-budget",
                    f"paged-KV pool of head {name!r} "
                    f"({decode_pages} pages x {page_size} tokens = "
                    f"{pool / _MB:.1f} MB) does not fit on {host!r}: "
                    f"weights already use {used / _MB:.1f} of "
                    f"{cap[host] / _MB:.1f} MB", entity=name,
                    hint="lower decode_pages/page_size in serve(), or "
                         "move the head to a larger device"))
    return diags


def _check_sharing(models: list[ModelSpec]) -> list[Diagnostic]:
    """Shared signatures must agree on shape/dtype-bearing spec fields
    across every task that reuses them (paper Insight 4: same
    architecture AND parameters)."""
    diags: list[Diagnostic] = []
    seen: dict[str, tuple[ModuleSpec, str]] = {}
    reported: set[str] = set()
    for mdl in models:
        for m in mdl.modules:
            prev = seen.setdefault(m.name, (m, mdl.name))
            if prev[0] == m or m.name in reported:
                continue
            fields = [f for f in _SHARING_FIELDS
                      if getattr(prev[0], f) != getattr(m, f)]
            diags.append(Diagnostic(
                Severity.ERROR, "plan/signature-collision",
                f"module {m.name!r} is shared by models "
                f"{prev[1]!r} and {mdl.name!r} with incompatible specs "
                f"(differ on: {', '.join(fields) or 'unknown fields'})",
                entity=m.name,
                hint="rename one module, or align the specs so sharing "
                     "is legal"))
            reported.add(m.name)
    return diags


def _check_acyclic(models: list[ModelSpec]) -> list[Diagnostic]:
    """The module dependency graph (encoder -> head, per model) must be
    a DAG, or request routing could never schedule a topological order."""
    edges: dict[str, set[str]] = {}
    for mdl in models:
        for enc in mdl.encoders:
            edges.setdefault(enc.name, set()).add(mdl.head.name)
            edges.setdefault(mdl.head.name, set())
    indeg = {n: 0 for n in edges}
    for srcs in edges.values():
        for dst in srcs:
            indeg[dst] += 1
    queue = [n for n, d in indeg.items() if d == 0]
    visited = 0
    while queue:
        n = queue.pop()
        visited += 1
        for dst in edges[n]:
            indeg[dst] -= 1
            if indeg[dst] == 0:
                queue.append(dst)
    if visited == len(edges):
        return []
    cyclic = sorted(n for n, d in indeg.items() if d > 0)
    return [Diagnostic(
        Severity.ERROR, "plan/dependency-cycle",
        f"module dependency graph has a cycle through {cyclic}",
        entity=cyclic[0] if cyclic else None,
        hint="a module cannot be an encoder downstream of its own head; "
             "split the shared signature")]


def _check_reachable(placement: Placement, cluster: ClusterSpec,
                     models: list[ModelSpec],
                     dev_names: set[str]) -> list[Diagnostic]:
    """Every encoder host must have a usable link to at least one head
    host (a link with explicit zero/negative bandwidth is a partition —
    ``t_comm`` would divide by zero at runtime)."""

    def bw(src: str, dst: str) -> float:
        if src == dst:
            return float("inf")
        link = cluster.links.get((src, dst), cluster.links.get((dst, src)))
        return link[0] if link else cluster.default_bandwidth

    diags: list[Diagnostic] = []
    for mdl in models:
        head_hosts = [h for h in _hosts_for(placement, mdl.head, mdl)
                      if h in dev_names]
        if not head_hosts:
            continue                     # unmapped-module already covers it
        for enc in mdl.encoders:
            for h in _hosts_for(placement, enc, mdl):
                if h not in dev_names:
                    continue
                if all(bw(h, g) <= 0 for g in head_hosts):
                    diags.append(Diagnostic(
                        Severity.ERROR, "plan/unreachable-route",
                        f"encoder {enc.name!r} on {h!r} cannot reach any "
                        f"head host {head_hosts} of model {mdl.name!r}: "
                        "all links have zero bandwidth", entity=h,
                        hint="fix the link matrix or co-locate the "
                             "encoder with the head"))
    return diags


def _check_refcounts(placement: Placement, registry,
                     models: list[ModelSpec]) -> list[Diagnostic]:
    diags: list[Diagnostic] = []
    live = {m.name for mdl in models for m in mdl.modules}
    for name in registry.modules:
        refs = registry.refcount(name)
        placed = len(placement.assignment.get(name, ()))
        if refs > 0 and placed == 0 and name not in \
                placement.infeasible_modules:
            # suffixed no-share keys satisfy the per-model check above
            # but the registry check is only meaningful for shared keys
            if any(k.startswith(f"{name}::") for k in placement.assignment):
                continue
            diags.append(Diagnostic(
                Severity.ERROR, "plan/refcount-mismatch",
                f"module {name!r} is referenced by {refs} model(s) but "
                f"placed on 0 devices", entity=name,
                hint="re-run plan() — the placement predates the last "
                     "add_model()"))
    for key in placement.assignment:
        base = key.split("::", 1)[0]
        if base not in live and registry.refcount(base) == 0:
            diags.append(Diagnostic(
                Severity.WARNING, "plan/stale-assignment",
                f"placement still assigns {key!r} but no live model "
                f"references it", entity=key,
                hint="evict() should have dropped it; re-run plan()"))
    return diags


def _check_plan_opts(placement_name: str,
                     plan_opts: dict) -> list[Diagnostic]:
    from repro.s2m3.policies import get_placement, strategy_options

    try:
        fn = get_placement(placement_name)
    except KeyError:
        return [Diagnostic(
            Severity.ERROR, "plan/unknown-strategy",
            f"placement strategy {placement_name!r} is not registered",
            entity=placement_name)]
    known = strategy_options(fn)
    if known is None:                    # open **kwargs: not checkable
        return []
    unknown = sorted(set(plan_opts) - set(known))
    return [Diagnostic(
        Severity.WARNING, "plan/unknown-option",
        f"plan option {o!r} is not accepted by strategy "
        f"{placement_name!r} (known: {sorted(known)}); it was silently "
        "ignored", entity=o,
        hint="fix the kwarg name in plan()") for o in unknown]
