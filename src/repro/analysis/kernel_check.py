"""Static Pallas kernel checker — no device execution.

For every kernel entry point in ``repro.kernels.ops`` and every shape
the zoo actually serves (gemma2-9b, llama3-8b, whisper-tiny, zamba2-7b,
xlstm-1.3b, mini-clip), this pass:

* computes the kernel's ``BlockPlan`` (``repro.kernels.plan``) — invalid
  grid/BlockSpec geometry becomes ``kernel/block-divisibility`` or
  ``kernel/invalid-geometry`` ERRORs instead of a trace-time crash;
* compares the per-program VMEM working set against a configurable
  budget (default 16 MiB/core) — ``kernel/vmem-budget`` WARNING;
* abstract-evals the real entry point with ``jax.eval_shape`` (traces
  the kernel body, runs nothing) and diffs the output pytree against
  the ``kernels/ref.py`` oracle — ``kernel/shape-drift`` /
  ``kernel/dtype-drift`` ERRORs.

Everything here is shape-level: it is safe to run on a CPU-only box and
in CI on every commit.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.analysis.diagnostics import Diagnostic, Severity

_MB = 1024 ** 2

#: the six public kernel entry points the checker must cover
ENTRY_POINTS = ("flash_attention", "decode_attention",
                "paged_decode_attention", "ssd_chunked",
                "ssd_intra_chunk", "slstm_scan")


@dataclass(frozen=True)
class KernelCase:
    """One (entry point, zoo shape) combination to vet."""

    name: str                    # e.g. "gemma2-9b/global-prefill"
    entry: str                   # key into repro.kernels.ops
    args: tuple                  # jax.ShapeDtypeStruct operands
    kwargs: dict = field(default_factory=dict)
    plan_fn: Callable[[], Any] | None = None      # -> BlockPlan, may raise
    expected_fn: Callable[[], Any] | None = None  # -> pytree of structs


def _sds(shape, dtype):
    import jax

    return jax.ShapeDtypeStruct(shape, dtype)


def _flash_case(name, *, B, S, H, D, T, K, dtype="bfloat16",
                causal=True, window=0, softcap=0.0,
                block_q=256, block_k=256):
    from repro.kernels import ref
    from repro.kernels.plan import flash_block_plan

    q = _sds((B, S, H, D), dtype)
    kv = _sds((B, T, K, D), dtype)
    kw = dict(causal=causal, window=window, softcap=softcap,
              block_q=block_q, block_k=block_k)

    def expected():
        import jax

        return jax.eval_shape(functools.partial(
            ref.flash_attention_ref, causal=causal, window=window,
            softcap=softcap), q, kv, kv)

    return KernelCase(
        name, "flash_attention", (q, kv, kv), kw,
        plan_fn=lambda: flash_block_plan(B, S, H, D, T, K,
                                         block_q, block_k, dtype),
        expected_fn=expected)


def _decode_case(name, *, B, H, D, T, K, dtype="bfloat16",
                 softcap=0.0, block_k=512):
    from repro.kernels import ref
    from repro.kernels.plan import decode_block_plan

    q = _sds((B, H, D), dtype)
    kv = _sds((B, T, K, D), dtype)
    lengths = _sds((B,), "int32")

    def expected():
        import jax

        return jax.eval_shape(functools.partial(
            ref.decode_attention_ref, softcap=softcap), q, kv, kv, lengths)

    return KernelCase(
        name, "decode_attention", (q, kv, kv, lengths),
        dict(softcap=softcap, block_k=block_k),
        plan_fn=lambda: decode_block_plan(B, H, D, T, K, block_k, dtype),
        expected_fn=expected)


def _paged_decode_case(name, *, B, H, D, T, K, page_size=16,
                       dtype="bfloat16", softcap=0.0):
    """Paged variant of the decode shape: same B/H/D/K as the dense
    decode case, with the T-token KV budget carved into pages (one
    sequence's worst case = T tokens, pool sized for B sequences)."""
    from repro.kernels import ref
    from repro.kernels.plan import paged_decode_block_plan

    n_max = -(-T // page_size)
    n_pages = B * n_max
    q = _sds((B, H, D), dtype)
    kv = _sds((n_pages, page_size, K, D), dtype)
    tables = _sds((B, n_max), "int32")
    lengths = _sds((B,), "int32")

    def expected():
        import jax

        return jax.eval_shape(functools.partial(
            ref.paged_decode_attention_ref, softcap=softcap),
            q, kv, kv, tables, lengths)

    return KernelCase(
        name, "paged_decode_attention", (q, kv, kv, tables, lengths),
        dict(softcap=softcap),
        plan_fn=lambda: paged_decode_block_plan(B, H, D, page_size, n_max,
                                                n_pages, K, dtype),
        expected_fn=expected)


def _ssd_cases(name, *, B, S, H, P, N, chunk, dtype="bfloat16"):
    from repro.kernels import ref
    from repro.kernels.plan import ssd_block_plan

    x = _sds((B, S, H, P), dtype)
    BC = _sds((B, S, N), dtype)
    dt = _sds((B, S, H), dtype)
    alog = _sds((H,), "float32")

    def chunked_expected():
        import jax

        return jax.eval_shape(ref.ssd_chunk_ref, x, BC, BC, dt, alog)

    chunked = KernelCase(
        f"{name}/chunked", "ssd_chunked", (x, BC, BC, dt, alog),
        dict(chunk=chunk),
        plan_fn=lambda: ssd_block_plan(B, S, H, P, N, chunk, dtype),
        expected_fn=chunked_expected)

    L = min(chunk, S)
    nc = max(S // L, 1)
    xi = _sds((B, nc, L, H, P), dtype)
    BCi = _sds((B, nc, L, N), dtype)
    dti = _sds((B, nc, L, H), dtype)
    # intra-chunk contract (kernels.ssd_scan docstring): y per-chunk
    # output, S_loc outgoing states, Lam chunk decays — all fp32
    intra = KernelCase(
        f"{name}/intra-chunk", "ssd_intra_chunk", (xi, BCi, BCi, dti, alog),
        plan_fn=lambda: ssd_block_plan(B, S, H, P, N, chunk, dtype),
        expected_fn=lambda: (_sds((B, nc, L, H, P), "float32"),
                             _sds((B, nc, H, N, P), "float32"),
                             _sds((B, nc, H), "float32")))
    return [chunked, intra]


def _slstm_case(name, *, B, S, d, H, hd, dtype="bfloat16", block_s=128):
    from repro.kernels import ref
    from repro.kernels.plan import slstm_block_plan

    pre = _sds((B, S, 4, d), dtype)
    R = _sds((4, H, hd, hd), dtype)

    def expected():
        import jax

        return jax.eval_shape(ref.slstm_cell_ref, pre, R)

    return KernelCase(
        name, "slstm_scan", (pre, R), dict(block_s=block_s),
        plan_fn=lambda: slstm_block_plan(B, S, d, H, hd, block_s, dtype),
        expected_fn=expected)


def zoo_cases() -> list[KernelCase]:
    """The shapes the zoo's full() configs actually run, one case per
    (entry point, architecture) pair.  whisper-tiny's 1500-step audio
    encoder is checked at its padded S=1536 (1500 is not divisible by
    any power-of-two block; the deployment pads)."""
    from repro.configs import (
        gemma2_9b, llama3_8b, whisper_tiny, xlstm_1_3b, zamba2_7b,
    )

    g = gemma2_9b.full()
    l3 = llama3_8b.full()
    wt = whisper_tiny.full()
    zb = zamba2_7b.full()
    xl = xlstm_1_3b.full()

    cases = [
        _flash_case("gemma2-9b/global-prefill", B=1, S=2048,
                    H=g.n_heads, D=g.head_dim, T=2048, K=g.n_kv_heads,
                    softcap=g.attn_logit_softcap),
        _flash_case("gemma2-9b/local-prefill", B=1, S=2048,
                    H=g.n_heads, D=g.head_dim, T=2048, K=g.n_kv_heads,
                    softcap=g.attn_logit_softcap, window=g.sliding_window),
        _flash_case("llama3-8b/prefill", B=1, S=2048,
                    H=l3.n_heads, D=l3.head_dim, T=2048, K=l3.n_kv_heads),
        _flash_case("whisper-tiny/audio-prefill-padded", B=1, S=1536,
                    H=wt.n_heads, D=wt.head_dim, T=1536, K=wt.n_kv_heads,
                    causal=False),
        _flash_case("mini-clip/vision", B=8, S=16, H=4, D=16, T=16, K=4),
        _decode_case("gemma2-9b/decode", B=4, H=g.n_heads, D=g.head_dim,
                     T=4096, K=g.n_kv_heads, softcap=g.attn_logit_softcap),
        _decode_case("llama3-8b/decode", B=4, H=l3.n_heads, D=l3.head_dim,
                     T=8192, K=l3.n_kv_heads),
        _paged_decode_case("gemma2-9b/paged-decode", B=4, H=g.n_heads,
                           D=g.head_dim, T=4096, K=g.n_kv_heads,
                           softcap=g.attn_logit_softcap),
        _paged_decode_case("llama3-8b/paged-decode", B=4, H=l3.n_heads,
                           D=l3.head_dim, T=8192, K=l3.n_kv_heads),
        _slstm_case("xlstm-1.3b/scan", B=1, S=512, d=xl.d_model,
                    H=xl.n_heads, hd=xl.d_model // xl.n_heads,
                    block_s=xl.xlstm_chunk),
    ]
    d_inner = zb.d_model * zb.mamba_expand
    cases += _ssd_cases("zamba2-7b", B=1, S=1024,
                        H=d_inner // zb.mamba_head_dim,
                        P=zb.mamba_head_dim, N=zb.ssm_state,
                        chunk=zb.mamba_chunk)
    return cases


def check_case(case: KernelCase,
               *, vmem_budget: int | None = None) -> list[Diagnostic]:
    import jax

    from repro.kernels import ops
    from repro.kernels.plan import VMEM_BYTES, KernelPlanError

    budget = VMEM_BYTES if vmem_budget is None else vmem_budget
    diags: list[Diagnostic] = []

    if case.plan_fn is not None:
        try:
            plan = case.plan_fn()
        except KernelPlanError as e:
            return [Diagnostic(
                Severity.ERROR, "kernel/block-divisibility", str(e),
                entity=case.name,
                hint="pad the sequence or pass a block size that divides "
                     "it — see repro.kernels.plan")]
        diags.append(Diagnostic(
            Severity.INFO, "kernel/summary",
            f"{case.entry}: grid={plan.grid}, "
            f"vmem~{plan.vmem_bytes / _MB:.2f} MB", entity=case.name))
        if plan.vmem_bytes > budget:
            diags.append(Diagnostic(
                Severity.WARNING, "kernel/vmem-budget",
                f"{case.entry} working set ~{plan.vmem_bytes / _MB:.2f} MB "
                f"exceeds the {budget / _MB:.0f} MB VMEM budget",
                entity=case.name,
                hint="shrink block_q/block_k/block_s for this shape"))

    entry = getattr(ops, case.entry)
    try:
        got = jax.eval_shape(functools.partial(entry, **case.kwargs),
                             *case.args)
    except Exception as e:  # tracing surfaced a real bug — report, don't die
        diags.append(Diagnostic(
            Severity.ERROR, "kernel/abstract-eval",
            f"{case.entry} failed abstract evaluation: "
            f"{type(e).__name__}: {e}", entity=case.name))
        return diags

    if case.expected_fn is not None:
        want = case.expected_fn()
        got_l = jax.tree_util.tree_leaves(got)
        want_l = jax.tree_util.tree_leaves(want)
        if len(got_l) != len(want_l):
            diags.append(Diagnostic(
                Severity.ERROR, "kernel/shape-drift",
                f"{case.entry} returns {len(got_l)} array(s), oracle "
                f"returns {len(want_l)}", entity=case.name))
            return diags
        for i, (gleaf, wleaf) in enumerate(zip(got_l, want_l)):
            if tuple(gleaf.shape) != tuple(wleaf.shape):
                diags.append(Diagnostic(
                    Severity.ERROR, "kernel/shape-drift",
                    f"{case.entry} output[{i}] shape "
                    f"{tuple(gleaf.shape)} != oracle {tuple(wleaf.shape)}",
                    entity=case.name,
                    hint="kernel and kernels/ref.py disagree — fix "
                         "whichever drifted"))
            elif gleaf.dtype != wleaf.dtype:
                diags.append(Diagnostic(
                    Severity.ERROR, "kernel/dtype-drift",
                    f"{case.entry} output[{i}] dtype {gleaf.dtype} != "
                    f"oracle {wleaf.dtype}", entity=case.name,
                    hint="check the final astype in the kernel epilogue"))
    return diags


def check_kernels(*, vmem_budget: int | None = None,
                  cases: list[KernelCase] | None = None) -> list[Diagnostic]:
    """Run every case (default: the full zoo sweep) and concatenate
    findings.  Covers all of ``ENTRY_POINTS`` by construction."""
    cs = zoo_cases() if cases is None else cases
    diags: list[Diagnostic] = []
    for c in cs:
        diags.extend(check_case(c, vmem_budget=vmem_budget))
    return diags
