"""zamba2-7b — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].

81 mamba blocks; one *weight-shared* attention+MLP block is invoked
after every 6 mamba blocks (13 invocations + 3 tail mamba blocks).  The
real model's per-invocation LoRA deltas and 2x-width concat input are
simplified away (DESIGN.md §4).  Sub-quadratic at decode: SSM state +
windowless attention reads are linear per token.
"""

from repro.common.config import ArchConfig, register_arch


def full() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
        d_ff=14336, vocab_size=32000, head_dim=112,
        ssm_state=64, mamba_head_dim=64, mamba_expand=2,
        mamba_conv_width=4, mamba_chunk=128,
        n_mamba_per_super=6, shared_attn_d_ff=14336,
        sub_quadratic=True,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b", family="hybrid",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, head_dim=16,
        ssm_state=16, mamba_head_dim=16, mamba_expand=2,
        mamba_conv_width=4, mamba_chunk=8,
        n_mamba_per_super=2, shared_attn_d_ff=128,
        sub_quadratic=True,
    )


register_arch("zamba2-7b", full, smoke)
