"""Architecture config registry: importing this package registers all archs."""

from repro.configs import (  # noqa: F401
    deepseek_v3_671b,
    gemma2_9b,
    granite_moe_3b_a800m,
    internvl2_1b,
    llama3_405b,
    llama3_8b,
    tinyllama_1_1b,
    whisper_tiny,
    xlstm_1_3b,
    zamba2_7b,
)
from repro.configs import s2m3_zoo  # noqa: F401  (the paper's own 14-model zoo)
