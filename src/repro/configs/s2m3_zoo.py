"""The paper's own 14-model testbed zoo (Tables II & V).

Two granularities:
* ``ZOO`` — ModelSpec-level data (module names + param counts from
  Table V / Table VI) consumed by the placement/routing simulator to
  reproduce the paper's tables at full scale.
* ``CLIP_CONFIGS`` — small *runnable* CLIP configs used by the serving
  engine demo and the split-vs-monolithic equivalence tests.
"""

from __future__ import annotations

from repro.models.clip import ClipConfig

M = 1_000_000
B = 1_000_000_000

# module name -> parameter count (Table V; text sizes back-derived from
# Table VI totals where the paper gives a range)
MODULE_PARAMS: dict[str, int] = {
    # vision encoders
    "resnet-50": 38 * M,
    "resnet-101": 56 * M,
    "resnet-50x4": 87 * M,
    "resnet-50x16": 168 * M,
    "resnet-50x64": 421 * M,
    "vit-b/32": 88 * M,
    "vit-b/16": 86 * M,
    "vit-l/14": 304 * M,
    "vit-l/14@336": 304 * M,
    "openclip-vit-h/14": 630 * M,
    # text encoders
    "clip-trf-38m": 38 * M,
    "clip-trf-59m": 59 * M,
    "clip-trf-85m": 85 * M,
    "clip-trf-151m": 151 * M,
    "openclip-trf": 302 * M,
    # audio encoder
    "audio-vit-b": 85 * M,
    # language models (task heads)
    "vicuna-7b": 7 * B,
    "vicuna-13b": 13 * B,
    "phi-3-mini": int(3.8 * B),
    "tinyllama-1.1b": int(1.1 * B),
    "gpt2": 124 * M,
    # parameter-free heads
    "cosine-similarity": 0,
    "infonce": 0,
    "classifier": 1 * M,
}

# model -> (task, encoder modules, head module)   [Table II]
ZOO: dict[str, tuple[str, tuple[str, ...], str]] = {
    # image-text retrieval (9 CLIP variants)
    "clip-resnet-50": ("retrieval", ("resnet-50", "clip-trf-38m"), "cosine-similarity"),
    "clip-resnet-101": ("retrieval", ("resnet-101", "clip-trf-38m"), "cosine-similarity"),
    "clip-resnet-50x4": ("retrieval", ("resnet-50x4", "clip-trf-59m"), "cosine-similarity"),
    "clip-resnet-50x16": ("retrieval", ("resnet-50x16", "clip-trf-85m"), "cosine-similarity"),
    "clip-resnet-50x64": ("retrieval", ("resnet-50x64", "clip-trf-151m"), "cosine-similarity"),
    "clip-vit-b/32": ("retrieval", ("vit-b/32", "clip-trf-38m"), "cosine-similarity"),
    "clip-vit-b/16": ("retrieval", ("vit-b/16", "clip-trf-38m"), "cosine-similarity"),
    "clip-vit-l/14": ("retrieval", ("vit-l/14", "clip-trf-85m"), "cosine-similarity"),
    "clip-vit-l/14@336": ("retrieval", ("vit-l/14@336", "clip-trf-85m"), "cosine-similarity"),
    # VQA
    "encoder-only-vqa-s": ("vqa-enc", ("vit-b/16", "clip-trf-38m"), "classifier"),
    "encoder-only-vqa-l": ("vqa-enc", ("vit-l/14@336", "clip-trf-85m"), "classifier"),
    "llava-v1.5-7b": ("vqa-dec", ("vit-l/14@336",), "vicuna-7b"),
    "llava-next-7b": ("vqa-dec", ("vit-l/14@336",), "vicuna-7b"),
    "llava-v1.5-13b": ("vqa-dec", ("vit-l/14@336",), "vicuna-13b"),
    "llava-next-13b": ("vqa-dec", ("vit-l/14@336",), "vicuna-13b"),
    "xtuner-phi-3-mini": ("vqa-dec", ("vit-l/14@336",), "phi-3-mini"),
    "flint-v0.5-1b": ("vqa-dec", ("vit-l/14@336",), "tinyllama-1.1b"),
    "llava-v1.5-7b-s": ("vqa-dec", ("vit-b/16",), "vicuna-7b"),
    "flint-v0.5-1b-s": ("vqa-dec", ("vit-b/16",), "tinyllama-1.1b"),
    # cross-modal alignment
    "imagebind": ("alignment", ("openclip-vit-h/14", "openclip-trf", "audio-vit-b"),
                  "infonce"),
    # Table X multi-task variant: alignment built from the *shared* CLIP
    # modules plus an audio encoder (Insight 3 interchangeability)
    "alignment-vit-b": ("alignment", ("vit-b/16", "clip-trf-38m", "audio-vit-b"),
                        "infonce"),
    # image captioning
    "nlp-connect": ("captioning", ("vit-b/16",), "gpt2"),
    # image classification
    "clip-cls-vit-b/16": ("classification", ("vit-b/16",), "classifier"),
}

# small runnable CLIP configs for engine demos / equivalence tests
CLIP_CONFIGS: dict[str, ClipConfig] = {
    "mini-clip": ClipConfig(
        name="mini-clip", vision_layers=2, vision_width=64, vision_heads=4,
        text_layers=2, text_width=64, text_heads=4, vocab_size=256,
        embed_dim=32, n_image_tokens=16,
    ),
    "mini-clip-l": ClipConfig(
        name="mini-clip-l", vision_layers=4, vision_width=96, vision_heads=6,
        text_layers=2, text_width=64, text_heads=4, vocab_size=256,
        embed_dim=32, n_image_tokens=16,
    ),
}


def get_clip_config(name: str) -> ClipConfig:
    return CLIP_CONFIGS[name]
