"""llama3-405b — GQA, 128k vocab [arXiv:2407.21783]."""

from repro.common.config import ArchConfig, register_arch
from repro.configs.tinyllama_1_1b import QUAD_REASON, QUAD_SKIP


def full() -> ArchConfig:
    return ArchConfig(
        name="llama3-405b", family="dense",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
        d_ff=53248, vocab_size=128256, head_dim=128,
        rope_theta=500000.0, act_fn="silu",
        skip_shapes=QUAD_SKIP, skip_reason=QUAD_REASON,
        # 810 GB of bf16 weights cannot replicate over the data axes at
        # serving time: keep FSDP (per-layer all-gather) for all shapes.
        sharding_overrides={
            "prefill": {"embed": ("pod", "data")},
            "decode": {"embed": ("pod", "data")},
        },
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="llama3-405b", family="dense",
        n_layers=3, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=192, vocab_size=256, head_dim=8, rope_theta=500000.0,
    )


register_arch("llama3-405b", full, smoke)
