"""whisper-tiny — enc-dec, conv frontend stubbed [arXiv:2212.04356].

``n_layers`` counts decoder blocks; the encoder has its own 4.  Decode
shapes use the assignment's 32k sequence mechanically even though the
real model caps at 448 positions (documented, not silently changed).
S2M3 view: audio-encoder module + text-decoder head module.
"""

from repro.common.config import ArchConfig, register_arch


def full() -> ArchConfig:
    return ArchConfig(
        name="whisper-tiny", family="audio",
        n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
        d_ff=1536, vocab_size=51865, head_dim=64,
        is_encoder_decoder=True, n_encoder_layers=4, encoder_seq=1500,
        norm="layernorm", act_fn="gelu", use_rope=False,
        tie_embeddings=True,
        skip_shapes=("long_500k",),
        skip_reason="full-attention decoder: 524k context is quadratic",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="whisper-tiny", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, head_dim=16,
        is_encoder_decoder=True, n_encoder_layers=2, encoder_seq=16,
        norm="layernorm", act_fn="gelu", use_rope=False,
        tie_embeddings=True,
    )


register_arch("whisper-tiny", full, smoke)
