"""internvl2-1b — InternViT + InternLM2/Qwen2-0.5B backbone
[arXiv:2404.16821].

The vision frontend is a STUB per the assignment: ``input_specs``
provides precomputed patch embeddings.  S2M3 view: vision-encoder module
(stub+projector) + LLM head module — the flagship split/share arch.
"""

from repro.common.config import ArchConfig, register_arch
from repro.configs.tinyllama_1_1b import QUAD_REASON, QUAD_SKIP


def full() -> ArchConfig:
    return ArchConfig(
        name="internvl2-1b", family="vlm",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
        d_ff=4864, vocab_size=151655, head_dim=64,
        rope_theta=1e6, tie_embeddings=True,
        has_vision_stub=True, n_image_tokens=256,
        skip_shapes=QUAD_SKIP, skip_reason=QUAD_REASON,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="internvl2-1b", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        rope_theta=1e6, tie_embeddings=True,
        has_vision_stub=True, n_image_tokens=8,
    )


register_arch("internvl2-1b", full, smoke)
