"""gemma2-9b — local+global alternating attention, logit softcaps
[arXiv:2408.00118]."""

from repro.common.config import ArchConfig, register_arch
from repro.configs.tinyllama_1_1b import QUAD_SKIP


def full() -> ArchConfig:
    return ArchConfig(
        name="gemma2-9b", family="dense",
        n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
        d_ff=14336, vocab_size=256000, head_dim=256,
        attn_pattern=("local", "global"), sliding_window=4096,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        post_norm=True, act_fn="gelu_tanh", tie_embeddings=True,
        embed_scale_by_dim=True, rope_theta=10000.0,
        skip_shapes=QUAD_SKIP,
        skip_reason="global layers are full attention: 524k is quadratic",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="gemma2-9b", family="dense",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        attn_pattern=("local", "global"), sliding_window=8,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        post_norm=True, act_fn="gelu_tanh", tie_embeddings=True,
        embed_scale_by_dim=True,
    )


register_arch("gemma2-9b", full, smoke)
