"""deepseek-v3-671b — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437]."""

from repro.common.config import ArchConfig, register_arch
from repro.configs.tinyllama_1_1b import QUAD_REASON, QUAD_SKIP


def full() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=2048, vocab_size=129280,
        head_dim=128,
        n_experts=256, experts_top_k=8, n_shared_experts=1,
        moe_d_ff=2048, first_dense_layers=3, dense_d_ff=18432,
        router_aux_loss=0.001,
        use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
        qk_rope_dim=64, qk_nope_dim=128, v_head_dim=128,
        mtp_depth=1,
        skip_shapes=QUAD_SKIP, skip_reason=QUAD_REASON,
        # 1.3 TB of bf16 weights cannot replicate over the data axes at
        # serving time: keep FSDP (per-layer all-gather) for all shapes.
        sharding_overrides={
            "prefill": {"embed": ("pod", "data")},
            "decode": {"embed": ("pod", "data")},
        },
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=96, vocab_size=256, head_dim=16,
        n_experts=8, experts_top_k=2, n_shared_experts=1,
        moe_d_ff=96, first_dense_layers=1, dense_d_ff=128,
        router_aux_loss=0.001,
        use_mla=True, q_lora_rank=32, kv_lora_rank=16,
        qk_rope_dim=8, qk_nope_dim=16, v_head_dim=16,
        mtp_depth=1,
    )


register_arch("deepseek-v3-671b", full, smoke)
