"""tinyllama-1.1b — llama2-arch small [arXiv:2401.02385; hf].

S2M3 note: TinyLlama-1.1B is literally the task-head LLM of the paper's
Flint-v0.5-1B VQA model (Table II) — it is the sharing-demo arch.
"""

from repro.common.config import ArchConfig, register_arch

QUAD_SKIP = ("long_500k",)
QUAD_REASON = "pure full-attention stack: 524k context is quadratic"


def full() -> ArchConfig:
    return ArchConfig(
        name="tinyllama-1.1b", family="dense",
        n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4,
        d_ff=5632, vocab_size=32000, head_dim=64,
        rope_theta=10000.0, act_fn="silu",
        skip_shapes=QUAD_SKIP, skip_reason=QUAD_REASON,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="tinyllama-1.1b", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
    )


register_arch("tinyllama-1.1b", full, smoke)
