"""granite-moe-3b-a800m — 40 experts top-8
[hf:ibm-granite/granite-3.0 family].

EP note: 40 experts do not divide the 16-way model axis; padded to 48
with zero-initialized never-routed experts (DESIGN.md §3).
"""

from repro.common.config import ArchConfig, register_arch
from repro.configs.tinyllama_1_1b import QUAD_REASON, QUAD_SKIP


def full() -> ArchConfig:
    return ArchConfig(
        name="granite-moe-3b-a800m", family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=512, vocab_size=49155, head_dim=64,
        n_experts=40, experts_top_k=8, moe_d_ff=512, expert_pad_to=48,
        router_aux_loss=0.01, tie_embeddings=True,
        skip_shapes=QUAD_SKIP, skip_reason=QUAD_REASON,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="granite-moe-3b-a800m", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab_size=256, head_dim=16,
        n_experts=5, experts_top_k=2, moe_d_ff=64, expert_pad_to=6,
        router_aux_loss=0.01, tie_embeddings=True,
    )


register_arch("granite-moe-3b-a800m", full, smoke)
