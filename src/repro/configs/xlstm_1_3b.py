"""xlstm-1.3b — sLSTM + mLSTM blocks, 7:1 ratio [arXiv:2405.04517].

48 blocks = 6 groups of (7 mLSTM + 1 sLSTM).  mLSTM runs chunkwise-
parallel; sLSTM (memory mixing) is a lax.scan over time.  Fully
recurrent state at decode -> runs long_500k.
"""

from repro.common.config import ArchConfig, register_arch


def full() -> ArchConfig:
    return ArchConfig(
        name="xlstm-1.3b", family="ssm",
        n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50304, head_dim=512,
        mlstm_to_slstm=7, mlstm_proj_factor=2.0, slstm_proj_factor=1.3334,
        xlstm_chunk=128, sub_quadratic=True,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="xlstm-1.3b", family="ssm",
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=256, head_dim=16,
        mlstm_to_slstm=2, mlstm_proj_factor=2.0, slstm_proj_factor=1.3334,
        xlstm_chunk=8, sub_quadratic=True,
    )


register_arch("xlstm-1.3b", full, smoke)
