"""llama3-8b — GQA, 128k vocab [arXiv:2407.21783]."""

from repro.common.config import ArchConfig, register_arch
from repro.configs.tinyllama_1_1b import QUAD_REASON, QUAD_SKIP


def full() -> ArchConfig:
    return ArchConfig(
        name="llama3-8b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=128256, head_dim=128,
        rope_theta=500000.0, act_fn="silu",
        skip_shapes=QUAD_SKIP, skip_reason=QUAD_REASON,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="llama3-8b", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=160, vocab_size=256, head_dim=16, rope_theta=500000.0,
    )


register_arch("llama3-8b", full, smoke)
