"""``repro.obs`` — observability for the S2M3 serving stack.

Three layers, threaded through ``serving/{engine,scheduler,decode}``
and surfaced on the ``s2m3.Deployment`` facade:

* **Tracing** (``obs.trace``): ``Span``/``Tracer`` with an injectable
  monotonic clock.  The engine and the serving scheduler emit spans for
  admission wait, batch formation, encoder launches (tagged with their
  cross-task composition), prefill, and every paged-decode tick, keyed
  by request id so one request's life is one trace tree.
  ``Trace.to_chrome_trace()`` exports Chrome/Perfetto JSON.
* **Metrics** (``obs.metrics``): a lock-safe counter/gauge/histogram
  registry.  The scheduler, the decode streams, the ``PagePool`` and
  the engine register instruments on it; ``stats_dict()`` remains as a
  compatibility view.  ``obs.summary.slo_summary`` renders per-task
  p50/p99 and SLO-deadline attainment from the histograms.
* **Drift** (``obs.drift``): ``Deployment.compare(workload)`` runs
  ``simulate()`` and ``serve()`` on the same ``Request`` objects and
  reports predicted-vs-measured per-module latency ratios, route
  divergences, and queue-model error — the ROADMAP's
  "sim routes == real devices" invariant, checked continuously.

CLI: ``python -m repro.obs trace out.json`` (demo trace export),
``python -m repro.obs drift`` (demo drift report),
``python -m repro.obs --self-test`` (span nesting, metrics thread
safety, instrument-lock lint — wired into ``python -m repro.analysis
--self``).
"""

from repro.obs.drift import DriftReport, compare_deployment
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.summary import format_slo_summary, slo_summary
from repro.obs.trace import Span, Trace, Tracer

__all__ = [
    "Counter", "DriftReport", "Gauge", "Histogram", "MetricsRegistry",
    "Span", "Trace", "Tracer", "compare_deployment",
    "format_slo_summary", "slo_summary",
]
