"""Span/Tracer core: per-request trace trees with an injectable clock.

A ``Span`` is one timed interval — a module phase ("encode", "prefill",
"decode_tick", ...), keyed by the request id it belongs to and linked to
its parent span, so every request's life through the serving stack is
one tree rooted at its "request" span.  ``Span`` iterates as the legacy
``(module, phase, t0, t1)`` timeline tuple, so existing consumers of
``InferenceResult.timeline`` keep working unchanged.

``Tracer`` is the collector: thread-safe, append-only, with an
injectable monotonic clock (tests pass a fake; the serving scheduler
passes its epoch-relative ``_now``).  ``Tracer.trace`` snapshots a
``Trace`` — queryable (``spans_for``/``tree``/``validate``) and
exportable as Chrome-trace/Perfetto JSON (``to_chrome_trace``), where
each request id becomes one track.
"""

from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable

#: tolerance when checking child-within-parent nesting (clock jitter)
_EPS = 1e-9


@dataclass
class Span:
    """One timed interval of a request's life.

    Iterating yields ``(name, phase, t0, t1)`` — the legacy timeline
    tuple shape of ``serving.engine.InferenceResult``.
    """

    name: str                    # module (or "request" for roots)
    phase: str                   # encode | head | prefill | decode | ...
    t0: float
    t1: float | None = None
    rid: int | None = None
    sid: int = -1                # tracer-assigned span id
    parent: int | None = None    # parent span id (None = root)
    attrs: dict[str, Any] = field(default_factory=dict)

    @property
    def dur(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0

    @property
    def open(self) -> bool:
        return self.t1 is None

    def __iter__(self):
        yield self.name
        yield self.phase
        yield self.t0
        yield self.t1


class Tracer:
    """Thread-safe span collector with an injectable monotonic clock."""

    def __init__(self, clock: Callable[[], float] | None = None):
        self.clock = clock or time.perf_counter
        self._lock = threading.Lock()
        self._spans: list[Span] = []
        self._next_sid = 0

    def begin(self, name: str, phase: str, *, rid: int | None = None,
              parent: int | None = None, t0: float | None = None,
              **attrs: Any) -> int:
        """Open a span; returns its id for ``end()`` / child parenting."""
        span = Span(name, phase, self.clock() if t0 is None else t0,
                    rid=rid, parent=parent, attrs=dict(attrs))
        with self._lock:
            span.sid = self._next_sid
            self._next_sid += 1
            self._spans.append(span)
        return span.sid

    def end(self, sid: int, *, t1: float | None = None,
            **attrs: Any) -> Span:
        """Close a span by id (idempotent: re-ending keeps the first t1)."""
        if sid < 0:
            raise ValueError(f"invalid span id {sid}")
        t = self.clock() if t1 is None else t1
        with self._lock:
            span = self._spans[sid]
            if span.t1 is None:
                span.t1 = t
            if attrs:
                span.attrs.update(attrs)
            return span

    def record(self, name: str, phase: str, t0: float, t1: float, *,
               rid: int | None = None, parent: int | None = None,
               **attrs: Any) -> Span:
        """Record an already-measured interval as a closed span."""
        sid = self.begin(name, phase, rid=rid, parent=parent, t0=t0,
                         **attrs)
        return self.end(sid, t1=t1)

    @contextmanager
    def span(self, name: str, phase: str, *, rid: int | None = None,
             parent: int | None = None, **attrs: Any):
        sid = self.begin(name, phase, rid=rid, parent=parent, **attrs)
        try:
            yield sid
        finally:
            self.end(sid)

    @property
    def trace(self) -> "Trace":
        with self._lock:
            return Trace(list(self._spans))


class Trace:
    """An immutable snapshot of collected spans, queryable as per-rid
    trees and exportable as Chrome-trace JSON."""

    def __init__(self, spans: list[Span]):
        self.spans = list(spans)
        self._by_sid = {s.sid: s for s in self.spans}

    def __len__(self) -> int:
        return len(self.spans)

    def rids(self) -> list[int]:
        return sorted({s.rid for s in self.spans if s.rid is not None})

    def spans_for(self, rid: int) -> list[Span]:
        return [s for s in self.spans if s.rid == rid]

    def children(self, sid: int) -> list[Span]:
        return [s for s in self.spans if s.parent == sid]

    def roots(self, rid: int | None = None) -> list[Span]:
        spans = self.spans if rid is None else self.spans_for(rid)
        return [s for s in spans
                if s.parent is None or s.parent not in self._by_sid]

    def tree(self, rid: int) -> Span:
        """The single root span of one request's trace tree."""
        roots = self.roots(rid)
        if len(roots) != 1:
            raise ValueError(
                f"trace for rid {rid} has {len(roots)} roots, expected 1 "
                f"({[s.name for s in roots]})")
        return roots[0]

    def validate(self, rid: int | None = None) -> list[str]:
        """Well-formedness problems (empty list = a contiguous tree):
        unclosed spans, orphan parents, children outside their parent's
        interval, multiple roots per rid."""
        spans = self.spans if rid is None else self.spans_for(rid)
        problems: list[str] = []
        for s in spans:
            where = f"{s.name}/{s.phase} (sid {s.sid}, rid {s.rid})"
            if s.t1 is None:
                problems.append(f"unclosed span {where}")
                continue
            if s.parent is not None:
                p = self._by_sid.get(s.parent)
                if p is None:
                    problems.append(
                        f"orphan span {where}: parent sid {s.parent} "
                        "does not exist")
                    continue
                if p.rid is not None and s.rid is not None \
                        and p.rid != s.rid:
                    problems.append(
                        f"span {where} parented across rids "
                        f"({s.rid} under {p.rid})")
                if p.t1 is not None and (s.t0 < p.t0 - _EPS
                                         or s.t1 > p.t1 + _EPS):
                    problems.append(
                        f"span {where} [{s.t0:.6f}, {s.t1:.6f}] escapes "
                        f"parent {p.name}/{p.phase} "
                        f"[{p.t0:.6f}, {p.t1:.6f}]")
        for r in ({s.rid for s in spans if s.rid is not None}
                  if rid is None else [rid]):
            roots = self.roots(r)
            if len(roots) != 1:
                problems.append(
                    f"rid {r} has {len(roots)} root spans, expected 1")
        return problems

    def to_chrome_trace(self) -> dict:
        """Chrome-trace/Perfetto JSON object format: one complete ("X")
        event per closed span, one track (tid) per request id."""
        events = []
        for s in self.spans:
            if s.t1 is None:
                continue
            args = {"sid": s.sid, **s.attrs}
            if s.parent is not None:
                args["parent"] = s.parent
            events.append({
                "name": f"{s.name}:{s.phase}",
                "cat": s.phase,
                "ph": "X",
                "ts": round(s.t0 * 1e6, 3),       # us, per the spec
                "dur": round(s.dur * 1e6, 3),
                "pid": 0,
                "tid": s.rid if s.rid is not None else -1,
                "args": args,
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def save(self, path) -> None:
        """Write the Chrome-trace JSON (open in Perfetto / chrome://tracing)."""
        from pathlib import Path

        Path(path).write_text(json.dumps(self.to_chrome_trace()) + "\n")
