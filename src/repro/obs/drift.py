"""Predicted-vs-actual drift analysis: does ``serve()`` do what
``simulate()`` promised?

``compare_deployment(dep, workload)`` drives the SAME ``Request``
objects through the event simulator and the live continuous-batching
scheduler, then lines the two up:

* **routes** — ``PlanReport.routes[rid]`` vs ``InferenceResult.devices``
  per module (the ROADMAP's "sim routes == real devices" invariant);
* **per-module latency** — mean predicted compute interval (sim
  ``comp``/``head_comp`` events) vs mean measured span duration, as a
  measured/predicted ratio;
* **per-request latency and queue-model error** — how far the
  simulator's end-to-end latencies sit from the scheduler's wall-clock
  measurements, in aggregate.

The latency *ratios* are the honest output: the simulator's absolute
scale comes from ``ClusterSpec`` FLOP rates, not from this machine, so
a stable ratio means the queue model ranks and proportions correctly
even when the absolute clock differs.
"""

from __future__ import annotations

from dataclasses import dataclass, field

#: timeline phases that represent module compute, comparable with the
#: simulator's comp/head_comp events
_MEASURED_PHASES = ("encode", "head", "prefill", "decode")


@dataclass(frozen=True)
class RouteDivergence:
    rid: int
    module: str
    predicted: str
    actual: str


@dataclass
class ModuleDrift:
    module: str
    predicted_s: float           # mean simulated compute interval
    measured_s: float            # mean measured span duration
    n: int                       # measured samples

    @property
    def ratio(self) -> float:
        return (self.measured_s / self.predicted_s
                if self.predicted_s > 0 else float("inf"))


@dataclass
class DriftReport:
    """One simulate()-vs-serve() comparison over a shared workload."""

    n_requests: int
    route_divergences: list[RouteDivergence] = field(default_factory=list)
    routes_checked: int = 0
    modules: dict[str, ModuleDrift] = field(default_factory=dict)
    # rid -> (predicted_s, measured_s)
    request_latency: dict[int, tuple[float, float]] = field(
        default_factory=dict)

    @property
    def n_route_divergences(self) -> int:
        return len(self.route_divergences)

    @property
    def predicted_mean_latency(self) -> float:
        xs = [p for p, _ in self.request_latency.values()]
        return sum(xs) / len(xs) if xs else 0.0

    @property
    def measured_mean_latency(self) -> float:
        xs = [m for _, m in self.request_latency.values()]
        return sum(xs) / len(xs) if xs else 0.0

    @property
    def queue_model_error(self) -> float:
        """Relative error of the simulator's mean end-to-end latency
        against the measured mean (0 = perfect queue model)."""
        p, m = self.predicted_mean_latency, self.measured_mean_latency
        if p <= 0:
            return float("inf") if m > 0 else 0.0
        return abs(m - p) / p

    def summary(self) -> str:
        lines = [f"drift report over {self.n_requests} request(s):"]
        lines.append(
            f"  routes: {self.routes_checked} module-route(s) checked, "
            f"{self.n_route_divergences} divergence(s)")
        for d in self.route_divergences:
            lines.append(f"    rid {d.rid} {d.module}: predicted "
                         f"{d.predicted} but ran on {d.actual}")
        for name in sorted(self.modules):
            md = self.modules[name]
            lines.append(
                f"  {name:24s} predicted {md.predicted_s * 1e3:8.3f} ms  "
                f"measured {md.measured_s * 1e3:8.3f} ms  "
                f"ratio {md.ratio:8.2f}x  (n={md.n})")
        lines.append(
            f"  e2e latency: predicted mean "
            f"{self.predicted_mean_latency * 1e3:.3f} ms vs measured mean "
            f"{self.measured_mean_latency * 1e3:.3f} ms "
            f"(queue-model error {self.queue_model_error:.1%})")
        return "\n".join(lines)


def compare_deployment(dep, workload, **serve_kwargs) -> DriftReport:
    """Run ``dep.simulate(workload)`` and ``dep.serve(workload)`` and
    reconcile them.  ``serve_kwargs`` flow to ``Deployment.serve``."""
    predicted = dep.simulate(workload)
    results = dep.serve(workload, **serve_kwargs)

    report = DriftReport(n_requests=len(workload))

    # predicted per-module compute intervals from the sim event trace
    pred_durs: dict[str, list[float]] = {}
    if predicted.sim is not None:
        for e in predicted.sim.events:
            if e.kind in ("comp", "head_comp"):
                pred_durs.setdefault(e.module, []).append(e.end - e.start)

    meas_durs: dict[str, list[float]] = {}
    for req, res in zip(workload, results):
        routes = predicted.routes.get(req.rid, {})
        for module, actual in sorted(res.devices.items()):
            want = routes.get(module)
            if want is None:
                continue                 # sim emitted no event (0-flop head)
            report.routes_checked += 1
            if want != actual:
                report.route_divergences.append(
                    RouteDivergence(req.rid, module, want, actual))
        for span in res.timeline:
            name, phase, t0, t1 = span
            if phase in _MEASURED_PHASES and t1 is not None:
                meas_durs.setdefault(name, []).append(t1 - t0)
        pred_lat = (predicted.sim.latencies.get(req.rid, 0.0)
                    if predicted.sim is not None else 0.0)
        report.request_latency[req.rid] = (pred_lat, res.latency_s)

    for module in sorted(set(pred_durs) & set(meas_durs)):
        ps, ms = pred_durs[module], meas_durs[module]
        report.modules[module] = ModuleDrift(
            module, sum(ps) / len(ps), sum(ms) / len(ms), len(ms))
    return report
