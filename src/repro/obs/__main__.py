"""CLI for the observability layer.

    python -m repro.obs trace out.json      # serve a demo two-task
                                            # workload, write the Chrome
                                            # trace (open in Perfetto)
    python -m repro.obs drift               # demo simulate-vs-serve
                                            # drift report
    python -m repro.obs --self-test         # span nesting + metrics
                                            # thread-safety + instrument
                                            # lint (CI gate; exit 1 on
                                            # failure)

The demo deployment is two tasks sharing one encoder — the smallest
workload that exercises cross-task batch coalescing, so the exported
trace shows the shared-encoder launches tagged with their batch
composition.
"""

from __future__ import annotations

import argparse
import sys


def _demo_deployment():
    import jax
    import jax.numpy as jnp

    from repro.core.cluster import ClusterSpec, DeviceSpec
    from repro.core.module import ModelSpec, ModuleSpec
    from repro.s2m3 import Deployment

    D = 16
    enc = ModuleSpec("demo-enc", "encoder", "vision", 4 * D * D,
                     flops_per_query=2e5)
    cls_head = ModuleSpec("demo-cls", "head", "task", 4 * D * 4,
                          flops_per_query=1e4)
    reg_head = ModuleSpec("demo-reg", "head", "task", 4 * D,
                          flops_per_query=1e4)
    w_enc = jax.random.normal(jax.random.PRNGKey(0), (D, D))
    w_cls = jax.random.normal(jax.random.PRNGKey(1), (D, 4))
    w_reg = jax.random.normal(jax.random.PRNGKey(2), (D, 1))
    builders = {
        "demo-enc": lambda: (lambda p, x: jnp.tanh(x @ p), w_enc),
        "demo-cls": lambda: (lambda p, e: e["vision"] @ p, w_cls),
        "demo-reg": lambda: (lambda p, e: e["vision"] @ p, w_reg),
    }
    cluster = ClusterSpec(devices=[
        DeviceSpec(f"dev{i}", 1024**3, 1e9) for i in range(2)])
    dep = (Deployment(cluster)
           .add_model(ModelSpec("classify", "classification",
                                (enc,), cls_head), builders)
           .add_model(ModelSpec("score", "regression", (enc,), reg_head))
           .plan("greedy", routing="paper")
           .materialize())
    return dep


def _demo_workload(n: int):
    import jax

    from repro.s2m3 import Request

    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16))
    return [Request(i, "classify" if i % 2 == 0 else "score", "dev0",
                    inputs={"vision": x}, slo_deadline=0.5)
            for i in range(n)]


def _cmd_trace(out: str, n: int) -> int:
    dep = _demo_deployment()
    dep.serve(_demo_workload(n))
    trace = dep.trace()
    problems = trace.validate()
    trace.save(out)
    print(f"served {n} demo request(s); wrote {len(trace)} span(s) "
          f"to {out} (open in https://ui.perfetto.dev)")
    for p in problems:
        print(f"MALFORMED: {p}")
    from repro.obs.summary import format_slo_summary, slo_summary

    print(format_slo_summary(slo_summary(dep.scheduler)))
    return 1 if problems else 0


def _cmd_drift(n: int) -> int:
    dep = _demo_deployment()
    report = dep.compare(_demo_workload(n))
    print(report.summary())
    return 0


def _cmd_self_test() -> int:
    from repro.analysis.diagnostics import errors, format_report
    from repro.obs.selftest import self_test

    diags = self_test()
    print(format_report(diags))
    return 1 if errors(diags) else 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="tracing / metrics / drift CLI for the S2M3 "
                    "serving stack")
    ap.add_argument("--self-test", action="store_true",
                    help="run the obs self-test (span nesting, metrics "
                         "thread-safety, instrument lint)")
    sub = ap.add_subparsers(dest="cmd")
    p_trace = sub.add_parser(
        "trace", help="serve a demo workload and export its Chrome trace")
    p_trace.add_argument("out", help="output JSON path")
    p_trace.add_argument("-n", type=int, default=6,
                         help="demo requests (default %(default)s)")
    p_drift = sub.add_parser(
        "drift", help="demo simulate-vs-serve drift report")
    p_drift.add_argument("-n", type=int, default=6)
    args = ap.parse_args(argv)

    if args.self_test:
        return _cmd_self_test()
    if args.cmd == "trace":
        return _cmd_trace(args.out, args.n)
    if args.cmd == "drift":
        return _cmd_drift(args.n)
    ap.print_help()
    return 2


if __name__ == "__main__":
    sys.exit(main())
