"""Observability self-test — the ``python -m repro.obs --self-test``
payload, also run by ``python -m repro.analysis --self``.

Three checks, each reported as ``Diagnostic``s so the analysis CLI can
gate CI on them:

* **span nesting** — a synthetic nested trace must validate clean, and
  the validator must actually flag planted orphans / escaping children
  / double roots (a validator that never fires is worse than none);
* **metrics thread safety** — hammer one counter/histogram from many
  threads; any lost update is an ERROR;
* **instrument-lock lint** — run the ``obs/unlocked-metric-mutation``
  rule over ``repro.obs`` itself, and prove the rule fires on a
  planted-bad instrument class.
"""

from __future__ import annotations

from repro.analysis.diagnostics import Diagnostic, Severity

_BAD_INSTRUMENT = '''
import threading

class RacyCounter:
    kind = "counter"

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n=1):
        self._value += n          # planted: mutation outside the lock
'''


def _check_span_nesting() -> list[Diagnostic]:
    from repro.obs.trace import Span, Trace, Tracer

    diags: list[Diagnostic] = []
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    tr = Tracer(clock=clock)
    root = tr.begin("request", "request", rid=1)
    enc = tr.begin("enc", "encode", rid=1, parent=root)
    tr.end(enc)
    tr.record("enc", "wait", t0=1.5, t1=2.0, rid=1, parent=root)
    tr.end(root)
    problems = tr.trace.validate(1)
    if problems:
        diags.append(Diagnostic(
            Severity.ERROR, "obs/span-nesting",
            f"well-formed synthetic trace failed validation: {problems}"))
    if tr.trace.tree(1).sid != root:
        diags.append(Diagnostic(
            Severity.ERROR, "obs/span-nesting",
            "tree() did not return the root span"))

    # the validator must flag planted malformations
    planted = Trace([
        Span("request", "request", 0.0, 10.0, rid=7, sid=0),
        Span("m", "encode", 2.0, 12.0, rid=7, sid=1, parent=0),   # escapes
        Span("m", "wait", 1.0, 2.0, rid=7, sid=2, parent=99),     # orphan
        Span("m", "head", 3.0, None, rid=7, sid=3, parent=0),     # unclosed
    ])
    found = "\n".join(planted.validate(7))
    for needle in ("escapes parent", "orphan", "unclosed"):
        if needle not in found:
            diags.append(Diagnostic(
                Severity.ERROR, "obs/span-nesting",
                f"validator failed to flag a planted {needle!r} span"))
    return diags


def _check_metrics_threading(n_threads: int = 8,
                             n_iter: int = 2000) -> list[Diagnostic]:
    import threading

    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()

    def work():
        c = reg.counter("selftest.hits", worker="shared")
        h = reg.histogram("selftest.lat")
        for i in range(n_iter):
            c.inc()
            h.observe(float(i))

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    want = n_threads * n_iter
    got = reg.value("selftest.hits", worker="shared")
    hist = reg.histogram("selftest.lat")
    diags: list[Diagnostic] = []
    if got != want:
        diags.append(Diagnostic(
            Severity.ERROR, "obs/metrics-thread-safety",
            f"counter lost updates under {n_threads} threads: "
            f"{got} != {want}"))
    if hist.count != want:
        diags.append(Diagnostic(
            Severity.ERROR, "obs/metrics-thread-safety",
            f"histogram lost observations: {hist.count} != {want}"))
    return diags


def _check_metric_lint() -> list[Diagnostic]:
    from pathlib import Path

    import repro.obs
    from repro.analysis.concurrency_lint import lint_paths, lint_source

    # the shipped instruments must be lint-clean
    diags = [d for d in lint_paths([Path(repro.obs.__file__).parent])
             if d.severity >= Severity.ERROR]
    # and the rule must fire on a planted-bad instrument
    planted = lint_source(_BAD_INSTRUMENT, "<planted>")
    if not any(d.code == "obs/unlocked-metric-mutation" for d in planted):
        diags.append(Diagnostic(
            Severity.ERROR, "obs/metric-lint",
            "obs/unlocked-metric-mutation rule failed to fire on a "
            "planted unlocked instrument mutation"))
    return diags


def self_test() -> list[Diagnostic]:
    """Run all obs self-checks; ERROR diagnostics mean the
    observability layer itself cannot be trusted."""
    diags = (_check_span_nesting() + _check_metrics_threading()
             + _check_metric_lint())
    if not any(d.severity >= Severity.ERROR for d in diags):
        diags.append(Diagnostic(
            Severity.INFO, "obs/self-test",
            "span nesting, metrics thread-safety, and instrument-lock "
            "lint all passed"))
    return diags
