"""Lock-safe metrics registry: counters, gauges, histograms.

One ``MetricsRegistry`` per serving scheduler (and one per engine for
engine-lifetime counters).  Instruments are get-or-created by name +
labels — ``reg.counter("serve.calls", module="mini-vit")`` — and every
instrument mutation happens under the registry's lock, which each
instrument holds a reference to.  That invariant is enforced statically
by ``repro.analysis.concurrency_lint``'s ``obs/unlocked-metric-mutation``
rule: any class declaring ``kind = "counter" | "gauge" | "histogram"``
must mutate its state only inside ``with self._lock`` blocks.

Histograms keep their raw samples (serving workloads here are
thousands of requests, not millions) so per-task p50/p99 and
SLO-attainment summaries (``obs.summary``) are exact, not bucketed.
The scheduler's legacy ``stats_dict()`` remains as a compatibility
view computed from these instruments.
"""

from __future__ import annotations

import threading
from typing import Any


def _key(name: str, labels: dict[str, Any]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Instrument:
    """Base: name + labels + the registry lock all mutations hold."""

    kind = ""

    def __init__(self, name: str, labels: dict[str, Any],
                 lock: threading.RLock):
        self.name = name
        self.labels = dict(labels)
        self._lock = lock

    @property
    def key(self) -> str:
        return _key(self.name, self.labels)


class Counter(Instrument):
    """Monotonically increasing count."""

    kind = "counter"

    def __init__(self, name, labels, lock):
        super().__init__(name, labels, lock)
        with self._lock:
            self._value = 0

    def inc(self, n: int | float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.key}: cannot inc by {n}")
        with self._lock:
            self._value += n

    @property
    def value(self):
        with self._lock:
            return self._value


class Gauge(Instrument):
    """Point-in-time value (``set``) with a running-max helper."""

    kind = "gauge"

    def __init__(self, name, labels, lock):
        super().__init__(name, labels, lock)
        with self._lock:
            self._value = 0

    def set(self, v) -> None:
        with self._lock:
            self._value = v

    def track_max(self, v) -> None:
        with self._lock:
            self._value = max(self._value, v)

    @property
    def value(self):
        with self._lock:
            return self._value


class Histogram(Instrument):
    """Exact distribution: raw samples plus count/sum/min/max."""

    kind = "histogram"

    def __init__(self, name, labels, lock):
        super().__init__(name, labels, lock)
        with self._lock:
            self._samples: list[float] = []
            self._sum = 0.0

    def observe(self, v: float) -> None:
        with self._lock:
            self._samples.append(float(v))
            self._sum += float(v)

    @property
    def count(self) -> int:
        with self._lock:
            return len(self._samples)

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    @property
    def mean(self) -> float:
        with self._lock:
            return (self._sum / len(self._samples)) if self._samples else 0.0

    @property
    def max(self) -> float:
        with self._lock:
            return max(self._samples, default=0.0)

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile over the raw samples (0 when empty)."""
        with self._lock:
            if not self._samples:
                return 0.0
            xs = sorted(self._samples)
        rank = max(0, min(len(xs) - 1,
                          round(p / 100.0 * (len(xs) - 1))))
        return xs[int(rank)]

    def summary(self) -> dict[str, float]:
        return {"count": self.count, "sum": round(self.sum, 6),
                "mean": round(self.mean, 6),
                "p50": round(self.percentile(50), 6),
                "p99": round(self.percentile(99), 6),
                "max": round(self.max, 6)}


class MetricsRegistry:
    """Get-or-create instrument store; one lock guards every mutation."""

    _KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}

    def __init__(self):
        self._lock = threading.RLock()
        self._instruments: dict[str, Instrument] = {}

    def _get_or_create(self, cls, name: str, labels: dict[str, Any]):
        key = _key(name, labels)
        with self._lock:
            inst = self._instruments.get(key)
            if inst is None:
                inst = cls(name, labels, self._lock)
                self._instruments[key] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"instrument {key!r} already registered as "
                    f"{inst.kind}, not {cls.kind}")
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get_or_create(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get_or_create(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get_or_create(Histogram, name, labels)

    # -- queries --------------------------------------------------------
    def get(self, name: str, **labels) -> Instrument | None:
        with self._lock:
            return self._instruments.get(_key(name, labels))

    def value(self, name: str, default=0, **labels):
        inst = self.get(name, **labels)
        return default if inst is None else inst.value

    def instruments(self, name: str | None = None) -> list[Instrument]:
        with self._lock:
            out = list(self._instruments.values())
        return out if name is None else [i for i in out if i.name == name]

    def total(self, name: str) -> float:
        """Sum of a counter/gauge family's values across all label sets."""
        return sum(i.value for i in self.instruments(name)
                   if not isinstance(i, Histogram))

    def label_values(self, name: str, label: str) -> list[str]:
        return sorted({str(i.labels[label]) for i in self.instruments(name)
                       if label in i.labels})

    def snapshot(self) -> dict[str, Any]:
        """Flat ``{key: value}`` view; histograms render their summary."""
        out: dict[str, Any] = {}
        for inst in self.instruments():
            out[inst.key] = (inst.summary()
                             if isinstance(inst, Histogram) else inst.value)
        return out
