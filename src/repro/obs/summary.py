"""Per-task latency and SLO-attainment summaries over the metrics
registry.

The serving scheduler observes every finished request into
``request.latency_s{model=...}`` histograms and counts
``slo.hit``/``slo.miss`` per model for requests that carried a
``slo_deadline``.  ``slo_summary`` renders those instruments as one row
per task — count, p50/p99 ms, and deadline hit-rate — without touching
scheduler internals, so it works on any ``MetricsRegistry`` that
follows the same naming.
"""

from __future__ import annotations

from typing import Any

from repro.obs.metrics import MetricsRegistry


def slo_summary(source) -> list[dict[str, Any]]:
    """One row per served task: request count, p50/p99 latency (ms),
    and SLO-deadline attainment.  ``source`` is a ``MetricsRegistry``
    or anything with a ``.metrics`` registry (a ``ServeScheduler``)."""
    reg = source if isinstance(source, MetricsRegistry) \
        else getattr(source, "metrics")
    rows = []
    for model in reg.label_values("request.latency_s", "model"):
        hist = reg.histogram("request.latency_s", model=model)
        hits = reg.value("slo.hit", model=model)
        misses = reg.value("slo.miss", model=model)
        with_slo = hits + misses
        rows.append({
            "model": model,
            "requests": hist.count,
            "p50_ms": round(hist.percentile(50) * 1e3, 3),
            "p99_ms": round(hist.percentile(99) * 1e3, 3),
            "mean_ms": round(hist.mean * 1e3, 3),
            "slo_requests": with_slo,
            "slo_attainment": (round(hits / with_slo, 4)
                               if with_slo else None),
        })
    return rows


def format_slo_summary(rows: list[dict[str, Any]]) -> str:
    if not rows:
        return "no served requests recorded"
    lines = [f"{'task':16s} {'n':>5s} {'p50_ms':>9s} {'p99_ms':>9s} "
             f"{'SLO':>7s}"]
    for r in rows:
        att = ("-" if r["slo_attainment"] is None
               else f"{r['slo_attainment']:.0%}")
        lines.append(f"{r['model']:16s} {r['requests']:5d} "
                     f"{r['p50_ms']:9.3f} {r['p99_ms']:9.3f} {att:>7s}")
    return "\n".join(lines)
