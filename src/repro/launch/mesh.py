"""Production mesh definitions.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_tag(multi_pod: bool) -> str:
    return "multipod2x16x16" if multi_pod else "pod16x16"


def require_devices(n: int):
    have = len(jax.devices())
    if have < n:
        raise RuntimeError(
            f"need {n} devices but have {have}; the dry-run entrypoint must "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (see launch/dryrun.py)")
