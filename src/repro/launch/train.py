"""Training launcher.

Single-host:   PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
                   --smoke --steps 50
Multi-host:    same command per host with JAX_COORDINATOR/JAX_PROCESS_ID etc.
               (jax.distributed.initialize is called when JAX_NUM_PROCESSES
               is set); the data pipeline shards by process automatically.

Production notes (1000+ nodes):
* XLA latency-hiding scheduler overlaps the gradient reduce-scatter with
  the backward pass: set
  XLA_FLAGS="--xla_tpu_enable_latency_hiding_scheduler=true" on TPU.
* Fault tolerance: checkpoints are atomic; on restart the loop resumes
  from the last COMMITTED step (see training/checkpoint.py).
* Elastic scaling: on pool change re-invoke with the new topology; the
  S2M3 placement replans with migration-minimal deltas
  (core/placement.replan).
"""

from __future__ import annotations

import argparse
import os
import pathlib
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--data", default="", help="token .bin file (synthetic "
                    "corpus if empty)")
    args = ap.parse_args()

    if os.environ.get("JAX_NUM_PROCESSES"):
        import jax

        jax.distributed.initialize()

    import jax
    import jax.numpy as jnp

    from repro.common.config import TrainConfig, get_config
    from repro.models.api import build_model
    from repro.training import checkpoint as ckpt
    from repro.training.data import DataConfig, TokenStream
    from repro.training.optimizer import init_state
    from repro.training.train_step import make_train_step

    cfg = get_config(args.arch, smoke=args.smoke)
    bundle = build_model(cfg, compute_dtype=jnp.float32, remat=args.remat)
    print(f"[train] {cfg.name} params={bundle.param_count():,} "
          f"procs={jax.process_count()}")

    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=10,
                       total_steps=args.steps, remat=args.remat,
                       microbatches=args.microbatches)
    state = init_state(bundle.init(jax.random.PRNGKey(0)), tcfg)
    ckdir = pathlib.Path(args.ckpt or f"/tmp/repro_train/{cfg.name}")
    if ckpt.latest_step(ckdir) is not None:
        state = ckpt.restore(state, ckdir,
                             process_index=jax.process_index())
        print(f"[train] resumed from step {int(state['step'])}")

    extra = {}
    if cfg.has_vision_stub:
        extra["image_embeds"] = ((cfg.n_image_tokens, cfg.d_model), "float32")
    if cfg.is_encoder_decoder:
        extra["audio_frames"] = ((cfg.encoder_seq, cfg.d_model), "float32")
    data = TokenStream(DataConfig(
        seq_len=args.seq, global_batch=args.batch,
        vocab_size=cfg.vocab_size, path=args.data or None,
        process_index=jax.process_index(),
        process_count=jax.process_count()), extra_features=extra)

    step_fn = jax.jit(make_train_step(bundle, tcfg), donate_argnums=(0,))
    t0 = time.time()
    start = int(state["step"])
    for i, batch in zip(range(start, args.steps), data):
        state, metrics = step_fn(state, {k: jnp.asarray(v)
                                         for k, v in batch.items()})
        if (i + 1) % 10 == 0:
            print(f"[train] step {i+1} loss={float(metrics['loss']):.4f} "
                  f"({(time.time()-t0)/(i+1-start):.2f}s/step)")
        if (i + 1) % args.ckpt_every == 0:
            ckpt.save_async(state, ckdir, step=i + 1,
                            process_index=jax.process_index())
    ckpt.save(state, ckdir, step=int(state["step"]),
              process_index=jax.process_index())
    print("[train] done")


if __name__ == "__main__":
    main()
