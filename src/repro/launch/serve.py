"""Serving launcher: continuous-batching LM server for any --arch.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --requests 8 --max-new 16

``--plan`` skips serving and instead prints the S2M3 deployment plan for
the arch over the paper's edge testbed (placement, memory ledger,
predicted latency) via the ``s2m3.Deployment`` facade.
"""

from __future__ import annotations

import argparse
import time


def plan_s2m3(cfg, routing: str) -> None:
    """Where would this arch live on the paper's testbed, and how fast
    would a request be?  One facade chain answers both."""
    from repro.core.module import distinct_modules
    from repro.core.profiles import install_profile, make_testbed
    from repro.core.zoo import arch_model_spec, request_for
    from repro.s2m3 import Deployment

    spec = arch_model_spec(cfg)
    cluster = make_testbed(with_server=True)
    install_profile(cluster, distinct_modules([spec]).values())
    dep = (Deployment(cluster)
           .add_model(spec)
           .plan(placement="greedy", routing=routing, replicate=True))
    report = dep.simulate([request_for(spec, 0, "desktop")])
    print(f"[serve] S2M3 plan for {cfg.name}:")
    print(report.summary())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--plan", action="store_true",
                    help="print the S2M3 placement plan and exit")
    ap.add_argument("--routing", default="queue_aware",
                    help="routing policy for --plan (paper | queue_aware)")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.common.config import get_config
    from repro.models.api import build_model
    from repro.serving.generator import GenRequest, LMServer

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.plan:
        plan_s2m3(cfg, args.routing)
        return
    bundle = build_model(cfg, compute_dtype=jnp.float32)
    print(f"[serve] {cfg.name} params={bundle.param_count():,}")
    server = LMServer(bundle, max_batch=args.max_batch,
                      cache_len=args.cache_len)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        extras = {}
        if cfg.has_vision_stub:
            extras["image_embeds"] = 0.1 * rng.standard_normal(
                (cfg.n_image_tokens, cfg.d_model)).astype(np.float32)
        if cfg.is_encoder_decoder:
            extras["audio_frames"] = 0.1 * rng.standard_normal(
                (cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        prompt = rng.integers(1, cfg.vocab_size,
                              size=rng.integers(2, 8)).tolist()
        server.submit(GenRequest(rid=i, prompt=prompt,
                                 max_new_tokens=args.max_new,
                                 temperature=args.temperature,
                                 extras=extras))
    t0 = time.time()
    done = server.run()
    dt = time.time() - t0
    total = sum(len(r.output) for r in done)
    for r in done[:4]:
        print(f"  req {r.rid}: {r.output[:12]}{'...' if len(r.output)>12 else ''}")
    print(f"[serve] {len(done)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s, {server._steps} batched decode steps)")


if __name__ == "__main__":
    main()
