"""Serving launcher: continuous-batching LM server for any --arch.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --requests 8 --max-new 16

``--plan`` skips serving and instead prints the S2M3 deployment plan for
the arch over the paper's edge testbed (placement, memory ledger,
predicted latency) via the ``s2m3.Deployment`` facade.
"""

from __future__ import annotations

import argparse
import time


def plan_s2m3(cfg, routing: str) -> None:
    """Where would this arch live on the paper's testbed, and how fast
    would a request be?  One facade chain answers both."""
    from repro.core.module import distinct_modules
    from repro.core.profiles import install_profile, make_testbed
    from repro.core.zoo import arch_model_spec, request_for
    from repro.s2m3 import Deployment

    spec = arch_model_spec(cfg)
    cluster = make_testbed(with_server=True)
    install_profile(cluster, distinct_modules([spec]).values())
    dep = (Deployment(cluster)
           .add_model(spec)
           .plan(placement="greedy", routing=routing, replicate=True))
    report = dep.simulate([request_for(spec, 0, "desktop")])
    print(f"[serve] S2M3 plan for {cfg.name}:")
    print(report.summary())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--plan", action="store_true",
                    help="print the S2M3 placement plan and exit")
    ap.add_argument("--routing", default="queue_aware",
                    help="routing policy for --plan (paper | queue_aware)")
    args = ap.parse_args()

    import jax.numpy as jnp
    import numpy as np

    from repro.common.config import get_config
    from repro.core.routing import Request
    from repro.models.api import build_model
    from repro.serving.scheduler import SchedulerConfig, lm_scheduler

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.plan:
        plan_s2m3(cfg, args.routing)
        return
    bundle = build_model(cfg, compute_dtype=jnp.float32)
    print(f"[serve] {cfg.name} params={bundle.param_count():,}")

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        inputs = {}
        if cfg.has_vision_stub:
            inputs["vision"] = 0.1 * rng.standard_normal(
                (cfg.n_image_tokens, cfg.d_model)).astype(np.float32)
        if cfg.is_encoder_decoder:
            inputs["audio"] = 0.1 * rng.standard_normal(
                (cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        prompt = tuple(rng.integers(1, cfg.vocab_size,
                                    size=rng.integers(2, 8)).tolist())
        reqs.append(Request(rid=i, model="lm", source="dev0", prompt=prompt,
                            max_new_tokens=args.max_new,
                            temperature=args.temperature,
                            inputs=inputs or None))
    t0 = time.time()
    if bundle.supports_paged_decode:
        sched = lm_scheduler(bundle, config=SchedulerConfig(
            decode_rows=args.max_batch, max_seq_len=args.cache_len,
            page_size=16,
            decode_pages=args.max_batch * (-(-args.cache_len // 16)) + 1))
        done = sched.serve(reqs)
        steps = sched.stats_dict()[cfg.name]["decode_steps"]
    else:
        # encoder-decoder families have no paged decode path: fall back
        # to solo prefill+decode per request on a bare engine
        sched = lm_scheduler(bundle)
        done = [sched.engine.generate(q) for q in reqs]
        steps = sum(len(r.output) for r in done)
    dt = time.time() - t0
    total = sum(len(r.output) for r in done)
    for r in done[:4]:
        toks = list(r.output[:12])
        print(f"  req {r.rid}: {toks}{'...' if len(r.output) > 12 else ''}")
    print(f"[serve] {len(done)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s, {steps} batched decode steps)")


if __name__ == "__main__":
    main()
