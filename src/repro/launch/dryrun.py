import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
init.  Do not set that flag anywhere global — smoke tests and benches
must see one device.

Single cell:   python -m repro.launch.dryrun --arch tinyllama-1.1b \
                   --shape train_4k [--multi-pod]
Full sweep:    python -m repro.launch.dryrun --all [--jobs 4]
               (spawns one subprocess per cell: isolates XLA state and
                returns memory to the OS between giant compiles)

Artifacts: results/dryrun/<arch>__<shape>__<mesh>.json containing
memory_analysis, cost_analysis, per-op collective bytes (parsed from the
optimized HLO), and the three-term roofline.
"""

import argparse
import json
import pathlib
import subprocess
import sys
import time

REPO = pathlib.Path(__file__).resolve().parents[3]
OUT_DIR = REPO / "results" / "dryrun"


# ---------------------------------------------------------------------------
# perf-hillclimb variants (§Perf in EXPERIMENTS.md): each is a named bundle
# of rule overrides / train-config / build options / arch-config tweaks.
# ---------------------------------------------------------------------------
VARIANTS: dict[str, dict] = {
    "baseline": {},
    # sequence parallelism: shard the query sequence over the model axis —
    # for low-head-count archs whose attention scores cannot head-shard
    "sp": {"rules": {"seq": "model"}},
    # activation-replicated decode for weight-huge models: keep 2D weight
    # sharding, replicate the (tiny) decode activations, move activations
    # not weights (partial matmul + small all-reduce instead of FSDP
    # all-gathering every layer's weights each step)
    "actrep": {"rules": {"batch": None}},
    # replicate attention weights over the model axis at decode so the
    # seq-sharded KV cache is consumed by distributed-softmax partials
    # instead of being all-gathered every layer
    "attnrep": {"rules": {"heads": None, "kv_heads": None}},
    # sp alone fails: wq's head sharding and x's seq sharding fight over
    # the model axis and heads win -> scores replicate.  sp2 releases the
    # (undivisible) head sharding so the sequence keeps the axis.
    "sp2": {"rules": {"seq": "model", "heads": None, "kv_heads": None}},
    # sp3: SP + explicit kv replication so the scores keep the seq shard
    "sp3": {"rules": {"seq": "model"}, "opts": {"attn_sp": True}},
    # bf16 masked-softmax chain (serving-grade numerics): halves the
    # dominant score-chain traffic the XLA path materializes
    "bf16sm": {"opts": {"softmax_dtype": "bfloat16"}},
    # force partial-matmul+all-reduce at decode: shard the activations'
    # hidden dim over data so it MATCHES the weights' contraction-dim
    # sharding (GSPMD only picks partial+AR on matched shardings)
    "actshard": {"rules": {"batch": None, "act_embed": "data"}},
    # one-hot masked KV-cache update: partitions elementwise over the
    # seq-sharded cache instead of GSPMD's involuntary full remat of the
    # scatter operand
    "blend": {"opts": {"cache_update": "blend"}},
    "blendshard": {"rules": {"batch": None, "act_embed": "data"},
                   "opts": {"cache_update": "blend"}},
    # shard_map cache insert: each chip updates its local (batch, seq)
    # tile; no involuntary remat, zero collectives for the update
    "cacheshard": {"opts": {"cache_update": "shard"}},
    # gather q (tiny) instead of the cache: distributed partial-softmax
    # decode attention over the seq-sharded cache
    "gatherq": {"opts": {"decode_attn": "gatherq"}},
    "gatherqshard": {"opts": {"decode_attn": "gatherq",
                              "cache_update": "shard"}},
    # full manual control: shard_map distributed-softmax decode attention
    # + shard_map cache insert (flash-decoding communication pattern)
    "smattn": {"opts": {"decode_attn": "shardmap",
                        "cache_update": "shard"}},
    # + activation hidden-dim sharding over data: weight FSDP gathers
    # become partial-matmul + small all-reduces
    "smattn2": {"opts": {"decode_attn": "shardmap", "cache_update": "shard"},
                "rules": {"batch": None, "act_embed": "data"}},
    # sLSTM scan unroll: recurrent weights CSE across unrolled steps
    "slstm8": {"cfg": {"slstm_unroll": 8}},
    "slstm32": {"cfg": {"slstm_unroll": 32}},
    "slstm128": {"cfg": {"slstm_unroll": 128}},
    # + shard the sLSTM recurrent weights over model: R reads and dR
    # all-reduces shrink 16x
    "slstm32shard": {"cfg": {"slstm_unroll": 32},
                     "rules": {"slstm_rec": "model"}},
    # remat policy: save matmul outputs instead of recomputing everything
    "dots": {"opts": {"remat": "dots"}},
    # gradient accumulation: 4 microbatches
    "mb4": {"tcfg": {"microbatches": 4}},
    "mb4dots": {"tcfg": {"microbatches": 4}, "opts": {"remat": "dots"}},
    "spdots": {"rules": {"seq": "model"}, "opts": {"remat": "dots"}},
    "slstm32dots": {"cfg": {"slstm_unroll": 32}, "opts": {"remat": "dots"}},
}


def _sharding_profile(cfg, shape, perf_variant: str):
    """Per-shape-kind logical rule overrides (+ arch-specific, + perf)."""
    kind_rules = {
        "train": {},
        # serving replicates weights over the data axes (no per-layer FSDP
        # gathers) unless the arch is too big to fit (giants override back)
        "prefill": {"embed": None},
        "decode": {"embed": None},
    }[shape.kind]
    rules = dict(kind_rules)
    rules.update(cfg.sharding_overrides.get(shape.kind, {}))
    rules.update(cfg.sharding_overrides.get(shape.name, {}))
    rules.update(VARIANTS.get(perf_variant, {}).get("rules", {}))
    return rules


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             perf_variant: str = "baseline", save_hlo: bool = False) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.common.config import SHAPES, TrainConfig, get_config
    from repro.common.hw import roofline_terms
    from repro.common.profiling import (
        collective_stats, cost_summary, memory_summary,
    )
    from repro.common.sharding import merge_rules, tree_shardings
    from repro.launch.mesh import make_production_mesh, mesh_tag, require_devices
    from repro.layers.initializers import abstract_tree, spec_param_count
    from repro.models.api import build_model
    from repro.training.optimizer import state_specs
    from repro.training.train_step import make_train_step

    cfg = get_config(arch)
    variant = VARIANTS.get(perf_variant, {})
    if variant.get("cfg"):
        cfg = cfg.with_overrides(**variant["cfg"])
    shape = SHAPES[shape_name]
    tag = mesh_tag(multi_pod)
    n_chips = 512 if multi_pod else 256
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": tag,
        "perf_variant": perf_variant, "n_chips": n_chips,
    }

    if shape_name in cfg.skip_shapes:
        record["skipped"] = cfg.skip_reason
        return record

    require_devices(512)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = merge_rules(_sharding_profile(cfg, shape, perf_variant))

    # Scan-over-layers keeps compile time tractable (the 126-layer x 512-dev
    # giants do not finish when unrolled).  XLA's cost_analysis would count
    # each scan body once, so flops/bytes/collectives come instead from
    # common.hlo_cost, which multiplies while-loop bodies by their
    # known_trip_count through the call graph.
    bundle = build_model(cfg, mesh=mesh, rules=rules,
                         **variant.get("opts", {}))
    n_params = bundle.param_count()
    n_active = bundle.active_param_count()
    record["n_params"] = n_params
    record["n_active_params"] = n_active
    giant = n_params > 100e9

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            tcfg = TrainConfig(
                moment_dtype="bfloat16" if giant else "float32",
                remat=variant.get("opts", {}).get("remat", "full"),
                **variant.get("tcfg", {}),
            )
            pdt = jnp.bfloat16 if giant else jnp.float32
            sspecs = state_specs(bundle.specs, tcfg)
            state_sds = abstract_tree(
                sspecs, pdt, tree_shardings(sspecs, rules, mesh))
            bspecs = bundle.batch_specs(shape)
            batch_sds = abstract_tree(
                bspecs, jnp.bfloat16, tree_shardings(bspecs, rules, mesh))
            step = make_train_step(bundle, tcfg)
            lowered = jax.jit(step, donate_argnums=(0,)).lower(
                state_sds, batch_sds)
            tokens = shape.global_batch * shape.seq_len
            record["model_flops"] = 6.0 * n_active * tokens
        else:
            pdt = jnp.bfloat16
            params_sds = abstract_tree(
                bundle.specs, pdt, tree_shardings(bundle.specs, rules, mesh))
            bspecs = bundle.batch_specs(shape)
            batch_sds = abstract_tree(
                bspecs, jnp.bfloat16, tree_shardings(bspecs, rules, mesh))
            cspecs = bundle.cache_specs(
                shape.global_batch, shape.seq_len, jnp.bfloat16)
            cache_sds = abstract_tree(
                cspecs, jnp.bfloat16, tree_shardings(cspecs, rules, mesh))
            if shape.kind == "prefill":
                lowered = jax.jit(bundle.prefill).lower(
                    params_sds, batch_sds, cache_sds)
                tokens = shape.global_batch * shape.seq_len
                record["model_flops"] = 2.0 * n_active * tokens
            else:  # decode: one token per sequence
                lowered = jax.jit(bundle.decode_step, donate_argnums=(2,)).lower(
                    params_sds, batch_sds["tokens"], cache_sds,
                    batch_sds["lengths"])
                record["model_flops"] = 2.0 * n_active * shape.global_batch

        record["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 2)

        mem = memory_summary(compiled)
        cost = cost_summary(compiled)
        print(compiled.memory_analysis())   # proves it fits
        print({k: v for k, v in cost.items() if k != "raw_keys"})

        from repro.common.hlo_cost import analyze as hlo_analyze

        hlo = compiled.as_text()
        rep = hlo_analyze(hlo)              # trip-count-aware per-device costs
        record["memory"] = mem
        record["hbm_per_device_gib"] = round(mem["total_bytes"] / 1024**3, 3)
        record["cost"] = {
            "flops": rep.flops, "bytes": rep.bytes,
            "xla_scan_once_flops": cost["flops"],
            "xla_scan_once_bytes": cost["bytes"],
        }
        record["collectives"] = {
            "bytes_by_op": rep.bytes_by_op,
            "count_by_op": rep.count_by_op,
            "total_bytes": rep.collective_bytes,
        }
        record["roofline"] = roofline_terms(
            rep.flops, rep.bytes, rep.collective_bytes, n_chips,
            per_device=True)
        record["model_vs_hlo_flops"] = (
            record["model_flops"] / (rep.flops * n_chips)
            if rep.flops else None)
        if save_hlo:
            hlo_path = OUT_DIR / f"{arch}__{shape_name}__{tag}.hlo.txt"
            hlo_path.write_text(hlo)
    return record


def _cell_main(args):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    rec = run_cell(args.arch, args.shape, args.multi_pod,
                   args.perf_variant, args.save_hlo)
    name = f"{args.arch}__{args.shape}__{'multipod2x16x16' if args.multi_pod else 'pod16x16'}"
    if args.perf_variant != "baseline":
        name += f"__{args.perf_variant}"
    out = OUT_DIR / f"{name}.json"
    out.write_text(json.dumps(rec, indent=1))
    status = "SKIP" if "skipped" in rec else "OK"
    print(f"[dryrun] {status} {name} "
          f"(lower {rec.get('lower_s', 0)}s compile {rec.get('compile_s', 0)}s "
          f"hbm/dev {rec.get('hbm_per_device_gib', '-')} GiB)")


def _sweep(jobs: int, multi_pod_only: bool, force: bool):
    from repro.common.config import SHAPES, list_archs

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    cells = []
    for arch in list_archs():
        for shape in SHAPES:
            for mp in ([True] if multi_pod_only else [False, True]):
                tag = "multipod2x16x16" if mp else "pod16x16"
                out = OUT_DIR / f"{arch}__{shape}__{tag}.json"
                if force or not out.exists():
                    cells.append((arch, shape, mp))
    print(f"[dryrun] {len(cells)} cells to run, {jobs} jobs")
    procs: list[tuple[subprocess.Popen, tuple]] = []
    failures = []
    idx = 0
    while idx < len(cells) or procs:
        while idx < len(cells) and len(procs) < jobs:
            arch, shape, mp = cells[idx]
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape]
            if mp:
                cmd.append("--multi-pod")
            log = OUT_DIR / f"log_{arch}__{shape}__{'mp' if mp else 'sp'}.txt"
            p = subprocess.Popen(
                cmd, stdout=log.open("w"), stderr=subprocess.STDOUT,
                env={**os.environ, "PYTHONPATH": str(REPO / "src")})
            procs.append((p, cells[idx]))
            idx += 1
        done = [(p, c) for p, c in procs if p.poll() is not None]
        procs = [(p, c) for p, c in procs if p.poll() is None]
        for p, c in done:
            if p.returncode != 0:
                failures.append(c)
                print(f"[dryrun] FAIL {c}")
            else:
                print(f"[dryrun] done {c}")
        if procs and not done:
            time.sleep(5)
    if failures:
        print(f"[dryrun] {len(failures)} failures: {failures}")
        sys.exit(1)
    print("[dryrun] sweep complete")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--perf-variant", default="baseline")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    if args.all:
        _sweep(args.jobs, args.multi_pod_only, args.force)
    else:
        assert args.arch and args.shape, "--arch and --shape required"
        _cell_main(args)


if __name__ == "__main__":
    main()
