"""Sharded AdamW (no optax on this box).

Optimizer state shards exactly like the parameters (ZeRO-3 by
construction under pjit).  ``moment_dtype="bfloat16"`` is the
DeepSeek-V3 trick that makes the 405B/671B optimizer fit 16 GB chips.
Optional int8 gradient compression (stochastic rounding) demonstrates
the collective-bytes reduction path; on a real multi-host backend the
cast happens before the cross-host reduce.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.common.config import TrainConfig
from repro.common.pytree import global_norm
from repro.layers.initializers import WSpec

F32 = jnp.float32


def lr_schedule(tcfg: TrainConfig, step):
    step = step.astype(F32)
    warm = jnp.minimum(step / jnp.maximum(tcfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - tcfg.warmup_steps)
        / jnp.maximum(tcfg.total_steps - tcfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return tcfg.learning_rate * warm * (0.1 + 0.9 * cos)


def state_specs(param_specs, tcfg: TrainConfig):
    """WSpec tree for the full optimizer state (drives shardings)."""
    mdt = jnp.dtype(tcfg.moment_dtype)

    def moment(ws: WSpec) -> WSpec:
        return dataclasses.replace(ws, init="zeros", dtype=mdt)

    is_ws = lambda x: isinstance(x, WSpec)
    return {
        "step": WSpec((), (), init="zeros", dtype=jnp.int32),
        "params": param_specs,
        "m": jax.tree.map(moment, param_specs, is_leaf=is_ws),
        "v": jax.tree.map(moment, param_specs, is_leaf=is_ws),
    }


def init_state(params, tcfg: TrainConfig):
    mdt = jnp.dtype(tcfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "step": jnp.zeros((), jnp.int32),
        "params": params,
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def compress_grads_int8(grads, key):
    """Stochastic-rounding int8 quantize->dequantize (per-leaf scale)."""

    def one(i, g):
        gf = g.astype(F32)
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        x = gf / scale
        k = jax.random.fold_in(key, i)
        noise = jax.random.uniform(k, x.shape, F32) - 0.5
        q = jnp.clip(jnp.round(x + noise), -127, 127).astype(jnp.int8)
        return q.astype(F32) * scale

    leaves, treedef = jax.tree.flatten(grads)
    return jax.tree.unflatten(
        treedef, [one(i, g) for i, g in enumerate(leaves)])


def adamw_update(state, grads, tcfg: TrainConfig, *, rng=None):
    step = state["step"] + 1
    lr = lr_schedule(tcfg, step)

    if tcfg.grad_compression == "int8":
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        grads = compress_grads_int8(grads, jax.random.fold_in(rng, step))

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, tcfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if tcfg.grad_clip > 0 else 1.0

    b1, b2, eps = tcfg.b1, tcfg.b2, tcfg.eps
    bc1 = 1.0 - b1 ** step.astype(F32)
    bc2 = 1.0 - b2 ** step.astype(F32)
    mdt = jnp.dtype(tcfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(F32) * clip
        m_new = b1 * m.astype(F32) + (1 - b1) * g
        v_new = b2 * v.astype(F32) + (1 - b2) * g * g
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + eps)
        if tcfg.weight_decay > 0 and p.ndim >= 2:     # no decay on norms/bias
            delta = delta + tcfg.weight_decay * p.astype(F32)
        p_new = p.astype(F32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(mdt), v_new.astype(mdt)

    flat_p, treedef = jax.tree.flatten(state["params"])
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_state = {
        "step": step,
        "params": jax.tree.unflatten(treedef, [o[0] for o in out]),
        "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
    }
    return new_state, {"lr": lr, "grad_norm": gnorm}
