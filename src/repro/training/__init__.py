"""Training substrate: sharded AdamW, train step, data, checkpointing."""
