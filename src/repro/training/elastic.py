"""Elasticity & straggler mitigation utilities.

Two layers of fault tolerance:

1. TRAINING: checkpoint/restart (training/checkpoint.py) + this module's
   ``ElasticTopology`` for re-planning the mesh when the pool changes —
   the batch is resharded over the surviving hosts and the step resumes
   from the last committed checkpoint.

2. SERVING: ``StragglerTracker`` keeps an EWMA of per-device module
   completion times; the router drops devices whose EWMA exceeds
   k x median from the candidate set (routing.simulate mirrors this via
   ``straggler_threshold``), and ``Redispatcher`` re-issues module calls
   that exceed a timeout on the next-fastest replica — the S2M3
   replication pass (placement replicate=True) provides the replicas.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable


@dataclasses.dataclass
class ElasticTopology:
    """Tracks pool membership; decides when a re-plan is needed."""
    hosts: set[str]
    generation: int = 0

    def update(self, alive: set[str]) -> bool:
        """Returns True if the topology changed (caller must re-plan +
        restore from checkpoint with the new mesh)."""
        if alive != self.hosts:
            self.hosts = set(alive)
            self.generation += 1
            return True
        return False

    def data_shards(self) -> list[str]:
        return sorted(self.hosts)


class StragglerTracker:
    def __init__(self, alpha: float = 0.3, threshold: float = 2.0):
        self.alpha = alpha
        self.threshold = threshold
        self.ewma: dict[str, float] = {}

    def record(self, device: str, seconds: float):
        prev = self.ewma.get(device)
        self.ewma[device] = (seconds if prev is None
                             else self.alpha * seconds + (1 - self.alpha) * prev)

    def healthy(self, candidates: list[str]) -> list[str]:
        known = [self.ewma[c] for c in candidates if c in self.ewma]
        if len(known) < 2:
            return candidates
        med = statistics.median(known)
        out = [c for c in candidates
               if self.ewma.get(c, med) <= self.threshold * med]
        return out or candidates

    def is_straggler(self, device: str) -> bool:
        if device not in self.ewma or len(self.ewma) < 2:
            return False
        med = statistics.median(self.ewma.values())
        return self.ewma[device] > self.threshold * med


class Redispatcher:
    """Re-issues a module call on a replica if the primary times out."""

    def __init__(self, tracker: StragglerTracker, timeout_factor: float = 3.0):
        self.tracker = tracker
        self.timeout_factor = timeout_factor

    def call(self, module: str, replicas: list[str],
             run_on: Callable[[str], object]):
        """run_on(device) -> result; blocks. Tries the healthiest replica,
        falls back in EWMA order on exception/timeout."""
        order = sorted(self.tracker.healthy(replicas),
                       key=lambda d: self.tracker.ewma.get(d, 0.0))
        errors = []
        for dev in order or replicas:
            t0 = time.perf_counter()
            try:
                out = run_on(dev)
                self.tracker.record(dev, time.perf_counter() - t0)
                return out, dev
            except Exception as e:  # noqa: BLE001 — deliberate failover
                self.tracker.record(dev, time.perf_counter() - t0)
                errors.append((dev, e))
        raise RuntimeError(f"all replicas failed for {module}: {errors}")
