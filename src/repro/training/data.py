"""Data pipeline: synthetic deterministic token stream + binary file loader.

Per-host sharding: each process takes a contiguous slice of the global
batch (process_index / process_count); the arrays produced here are the
per-host shard that ``jax.make_array_from_process_local_data`` would
assemble on a real multi-host deployment.
"""

from __future__ import annotations

import dataclasses
import pathlib

import numpy as np


@dataclasses.dataclass
class DataConfig:
    seq_len: int = 512
    global_batch: int = 8
    vocab_size: int = 32000
    seed: int = 1234
    path: str | None = None          # .bin of uint16/uint32 tokens
    process_index: int = 0
    process_count: int = 1


class TokenStream:
    """Deterministic synthetic corpus: Zipf-distributed tokens with
    long-range repeats so the loss is learnable (a model can beat the
    unigram entropy by copying)."""

    def __init__(self, dcfg: DataConfig, extra_features=None):
        self.cfg = dcfg
        self.extra = extra_features or {}
        if dcfg.path:
            raw = np.fromfile(dcfg.path, dtype=np.uint16).astype(np.int32)
            self._corpus = raw % dcfg.vocab_size
        else:
            rng = np.random.default_rng(dcfg.seed)
            n = max(1_000_000, 4 * dcfg.seq_len * dcfg.global_batch)
            zipf = rng.zipf(1.3, size=n).astype(np.int64)
            base = (zipf % max(dcfg.vocab_size - 2, 1)) + 1
            # inject copy structure: every 128 tokens repeat the previous 64
            base[128::128] = base[64::128][: len(base[128::128])]
            self._corpus = base.astype(np.int32)
        assert dcfg.global_batch % dcfg.process_count == 0
        self._local_batch = dcfg.global_batch // dcfg.process_count
        self._step = 0

    def __iter__(self):
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        d = self.cfg
        B, S = self._local_batch, d.seq_len
        n = len(self._corpus)
        out = np.empty((B, S + 1), np.int32)
        for i in range(B):
            gidx = self._step * d.global_batch \
                + d.process_index * B + i
            start = (gidx * (S + 1)) % (n - S - 2)
            out[i] = self._corpus[start : start + S + 1]
        self._step += 1
        batch = {
            "tokens": out[:, :-1],
            "targets": out[:, 1:],
            "mask": np.ones((B, S), np.float32),
        }
        rng = np.random.default_rng(d.seed + 7919 * self._step)
        for name, shape_dtype in self.extra.items():
            shape, dtype = shape_dtype
            batch[name] = rng.standard_normal((B, *shape)).astype(dtype) * 0.1
        return batch


def write_token_file(path: str | pathlib.Path, tokens: np.ndarray):
    np.asarray(tokens, dtype=np.uint16).tofile(str(path))
