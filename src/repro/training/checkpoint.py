"""Fault-tolerant sharded checkpointing.

Layout:  <dir>/step_<N>/proc<k>/<leaf-path>.npy  +  manifest.json
Writes go to a temp directory then atomically rename — a crash mid-save
never corrupts the latest checkpoint.  ``save_async`` offloads the
device->host copy + write to a thread so the train loop keeps stepping.
Restore validates shapes/dtypes against the target pytree.
"""

from __future__ import annotations

import json
import pathlib
import shutil
import threading

import jax
import numpy as np


def _leaf_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "__".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def save(state, directory, step: int, *, process_index: int = 0,
         keep: int = 3) -> pathlib.Path:
    directory = pathlib.Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f".tmp_step_{step:08d}_p{process_index}"
    proc = tmp / f"proc{process_index}"
    proc.mkdir(parents=True, exist_ok=True)

    manifest = {"step": step, "leaves": {}}
    for key, leaf in _leaf_paths(state):
        arr = np.asarray(jax.device_get(leaf))
        np.save(proc / f"{key}.npy", arr)
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    (proc / "manifest.json").write_text(json.dumps(manifest))

    final.mkdir(parents=True, exist_ok=True)
    dst = final / f"proc{process_index}"
    if dst.exists():
        shutil.rmtree(dst)
    (tmp / f"proc{process_index}").rename(dst)
    shutil.rmtree(tmp, ignore_errors=True)
    # mark complete (single-process: immediately; multi-host: proc0 decides)
    if process_index == 0:
        (final / "COMMITTED").write_text(str(step))
    _gc(directory, keep)
    return final


def save_async(state, directory, step: int, **kw) -> threading.Thread:
    host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
    t = threading.Thread(target=save, args=(host_state, directory, step),
                         kwargs=kw, daemon=True)
    t.start()
    return t


def latest_step(directory) -> int | None:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = []
    for p in directory.glob("step_*"):
        if (p / "COMMITTED").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore(target, directory, step: int | None = None, *,
            process_index: int = 0):
    """Restore into the structure of `target` (a pytree of arrays or
    ShapeDtypeStructs).  Returns the restored pytree."""
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    proc = directory / f"step_{step:08d}" / f"proc{process_index}"
    manifest = json.loads((proc / "manifest.json").read_text())

    flat = _leaf_paths(target)
    leaves = []
    for key, leaf in flat:
        info = manifest["leaves"].get(key)
        if info is None:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(proc / f"{key}.npy")
        want = tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {want}")
        leaves.append(arr.astype(leaf.dtype))
    treedef = jax.tree_util.tree_structure(target)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _gc(directory: pathlib.Path, keep: int):
    steps = sorted(
        (p for p in directory.glob("step_*") if (p / "COMMITTED").exists()),
        key=lambda p: int(p.name.split("_")[1]))
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)
