"""Train step builder: value_and_grad + microbatching + AdamW."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.config import TrainConfig
from repro.training.optimizer import adamw_update

F32 = jnp.float32


def make_train_step(bundle, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state', metrics)."""

    def loss_of(params, batch):
        loss, metrics = bundle.loss_fn(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def train_step(state, batch):
        if tcfg.microbatches > 1:
            k = tcfg.microbatches

            def split(x):
                b = x.shape[0]
                return x.reshape(k, b // k, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                loss, metrics, grads = single(state["params"], mb)
                acc_g, acc_l = acc
                acc_g = jax.tree.map(
                    lambda a, g: a + g.astype(F32) / k, acc_g, grads)
                return (acc_g, acc_l + loss / k), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, F32), state["params"])
            (grads, loss), _ = jax.lax.scan(
                body, (zero_g, jnp.zeros((), F32)), micro)
            metrics = {"loss": loss}
        else:
            loss, metrics, grads = single(state["params"], batch)

        new_state, opt_metrics = adamw_update(state, grads, tcfg)
        metrics = {**metrics, **opt_metrics}
        return new_state, metrics

    return train_step
