"""RMSNorm / LayerNorm."""

from __future__ import annotations

import jax.numpy as jnp

from repro.layers.initializers import WSpec


def norm_specs(d: int, kind: str = "rmsnorm"):
    specs = {"scale": WSpec((d,), ("norm",), init="ones")}
    if kind == "layernorm":
        specs["bias"] = WSpec((d,), ("norm",), init="zeros")
    return specs


def apply_norm(params, x, kind: str = "rmsnorm", eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * (jnp.mean(xf * xf, -1, keepdims=True) + eps) ** -0.5
        y = y * params["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * (var + eps) ** -0.5
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)
