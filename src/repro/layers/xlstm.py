"""xLSTM blocks: chunkwise-parallel mLSTM and recurrent sLSTM.

mLSTM is a matrix-memory cell with exponential gating; we implement the
standard stabilized chunkwise form (linear in sequence length) for
train/prefill and an O(1) step for decode.  sLSTM has memory mixing and
cannot be parallelized over time — it runs as a lax.scan (the paper's own
characterization).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.layers.initializers import WSpec
from repro.layers.norms import apply_norm, norm_specs


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_dims(cfg):
    d_in = int(cfg.mlstm_proj_factor * cfg.d_model)
    H = cfg.n_heads
    return d_in, H, d_in // H


def mlstm_specs(cfg):
    d, (d_in, H, hd) = cfg.d_model, mlstm_dims(cfg)
    return {
        "ln": norm_specs(d, cfg.norm),
        "w_up": WSpec((d, d_in), ("embed", "ssm_inner")),
        "w_gate": WSpec((d, d_in), ("embed", "ssm_inner")),
        "wq": WSpec((d_in, d_in), ("ssm_inner", None)),
        "wk": WSpec((d_in, d_in), ("ssm_inner", None)),
        "wv": WSpec((d_in, d_in), ("ssm_inner", None)),
        "wi": WSpec((d_in, H), ("ssm_inner", "ssm_heads"), init="small"),
        "wf": WSpec((d_in, H), ("ssm_inner", "ssm_heads"), init="small"),
        "b_i": WSpec((H,), ("ssm_heads",), init="zeros"),
        "b_f": WSpec((H,), ("ssm_heads",), init="ones"),
        "out_norm": norm_specs(d_in),
        "w_down": WSpec((d_in, d), ("ssm_inner", "embed")),
    }


def _mlstm_chunked(q, k, v, i_log, f_log, chunk: int, state=None):
    """Stabilized chunkwise mLSTM.

    q,k,v: (B,S,H,D); i_log,f_log: (B,S,H) log-space gates.
    state: (C (B,H,D,D), n (B,H,D), m (B,H)) or None.
    Returns (h (B,S,H,D), state').
    """
    B, S, H, D = q.shape
    L = min(chunk, S)
    if S % L:  # pad tail: i_log=-inf, f_log=0 (state-neutral)
        pad = L - S % L
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        i_log = jnp.pad(i_log, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
        f_log = jnp.pad(f_log, ((0, 0), (0, pad), (0, 0)))
        out, st = _mlstm_chunked(q, k, v, i_log, f_log, chunk, state)
        return out[:, :S], st
    nc = S // L
    scale = 1.0 / math.sqrt(D)

    qc = (q.astype(jnp.float32) * scale).reshape(B, nc, L, H, D)
    kc = k.astype(jnp.float32).reshape(B, nc, L, H, D)
    vc = v.astype(jnp.float32).reshape(B, nc, L, H, D)
    il = i_log.astype(jnp.float32).reshape(B, nc, L, H)
    fl = f_log.astype(jnp.float32).reshape(B, nc, L, H)

    cumf = jnp.cumsum(fl, axis=2)                     # (B,nc,L,H)
    b = il - cumf                                     # source weight logs
    F_L = cumf[:, :, -1, :]                           # (B,nc,H)

    if state is None:
        C0 = jnp.zeros((B, H, D, D), jnp.float32)
        n0 = jnp.zeros((B, H, D), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    causal = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(carry, inp):
        C, n, m = carry
        q_, k_, v_, b_, cumf_, FL_ = inp               # per-chunk slices
        # stabilizers
        m_intra = cumf_ + jax.lax.cummax(b_, axis=1)   # (B,L,H)
        m_inter = cumf_ + m[:, None, :]
        m_t = jnp.maximum(m_intra, m_inter)
        # intra scores
        logw = cumf_[:, :, None, :] - 0.0 + b_[:, None, :, :] - m_t[:, :, None, :]
        logw = jnp.where(causal[None, :, :, None], logw, -jnp.inf)
        w = jnp.exp(logw)                              # (B,t,s,H)
        qk = jnp.einsum("blhd,bmhd->blmh", q_, k_)
        h_num = jnp.einsum("blmh,blmh,bmhd->blhd", qk, w, v_)
        # inter contributions
        w_in = jnp.exp(cumf_ + m[:, None, :] - m_t)    # (B,L,H)
        h_num = h_num + jnp.einsum("blhd,bhde,blh->blhe", q_, C, w_in)
        n_dot = jnp.einsum("blhd,bhd->blh", q_, n)
        denom_intra = jnp.einsum("blmh,bmhd,blhd->blh", w, k_, q_)
        denom = denom_intra + n_dot * w_in
        h = h_num / jnp.maximum(jnp.abs(denom), jnp.exp(-m_t))[..., None]
        # state update
        Mloc = jnp.max(b_, axis=1)                     # (B,H)
        m_new = jnp.maximum(m + FL_, FL_ + Mloc)
        wk_s = jnp.exp(FL_[:, None, :] + b_ - m_new[:, None, :])  # (B,L,H)
        C_new = C * jnp.exp(m + FL_ - m_new)[:, :, None, None] + jnp.einsum(
            "blhd,blhe,blh->bhde", k_, v_, wk_s
        )
        n_new = n * jnp.exp(m + FL_ - m_new)[:, :, None] + jnp.einsum(
            "blhd,blh->bhd", k_, wk_s
        )
        return (C_new, n_new, m_new), h

    xs = tuple(
        jnp.moveaxis(t, 1, 0)
        for t in (qc, kc, vc, b, cumf, F_L)
    )
    (Cf, nf, mf), hs = jax.lax.scan(chunk_step, (C0, n0, m0), xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, D)
    return h.astype(q.dtype), (Cf, nf, mf)


def mlstm_recurrent_ref(q, k, v, i_log, f_log, state=None):
    """Naive per-step oracle for tests."""
    B, S, H, D = q.shape
    scale = 1.0 / math.sqrt(D)
    if state is None:
        C = jnp.zeros((B, H, D, D), jnp.float32)
        n = jnp.zeros((B, H, D), jnp.float32)
        m = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C, n, m = state

    def step(carry, inp):
        C, n, m = carry
        q_, k_, v_, il_, fl_ = inp
        m_new = jnp.maximum(fl_ + m, il_)
        f_ = jnp.exp(fl_ + m - m_new)
        i_ = jnp.exp(il_ - m_new)
        C = C * f_[:, :, None, None] + i_[:, :, None, None] * jnp.einsum(
            "bhd,bhe->bhde", k_, v_
        )
        n = n * f_[:, :, None] + i_[:, :, None] * k_
        num = jnp.einsum("bhd,bhde->bhe", q_ * scale, C)
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhd,bhd->bh", q_ * scale, n)), jnp.exp(-m_new)
        )
        return (C, n, m_new), num / den[..., None]

    xs = tuple(
        jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (q, k, v, i_log, f_log)
    )
    (Cf, nf, mf), hs = jax.lax.scan(step, (C, n, m), xs)
    return jnp.moveaxis(hs, 0, 1).astype(q.dtype), (Cf, nf, mf)


def mlstm_apply(params, x, cfg, *, state=None, impl: str = "chunked"):
    d_in, H, hd = mlstm_dims(cfg)
    dt = x.dtype
    x = apply_norm(params["ln"], x, cfg.norm, cfg.norm_eps)
    xu = jnp.einsum("bsd,de->bse", x, params["w_up"].astype(dt))
    z = jnp.einsum("bsd,de->bse", x, params["w_gate"].astype(dt))
    q = jnp.einsum("bse,ef->bsf", xu, params["wq"].astype(dt)).reshape(*x.shape[:2], H, hd)
    k = jnp.einsum("bse,ef->bsf", xu, params["wk"].astype(dt)).reshape(*x.shape[:2], H, hd)
    v = jnp.einsum("bse,ef->bsf", xu, params["wv"].astype(dt)).reshape(*x.shape[:2], H, hd)
    i_log = jnp.einsum("bse,eh->bsh", xu, params["wi"].astype(dt)).astype(jnp.float32) \
        + params["b_i"].astype(jnp.float32)
    f_log = jax.nn.log_sigmoid(
        jnp.einsum("bse,eh->bsh", xu, params["wf"].astype(dt)).astype(jnp.float32)
        + params["b_f"].astype(jnp.float32)
    )
    if impl == "recurrent" or x.shape[1] == 1:
        h, new_state = mlstm_recurrent_ref(q, k, v, i_log, f_log, state=state)
    else:
        h, new_state = _mlstm_chunked(q, k, v, i_log, f_log, cfg.xlstm_chunk, state=state)
    h = h.reshape(*x.shape[:2], d_in)
    h = apply_norm(params["out_norm"], h, cfg.norm, cfg.norm_eps)
    h = h * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", h, params["w_down"].astype(dt)), new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_dims(cfg):
    H = cfg.n_heads
    return H, cfg.d_model // H


def slstm_specs(cfg):
    d = cfg.d_model
    H, hd = slstm_dims(cfg)
    d_ff = int(cfg.slstm_proj_factor * d)
    gates = {}
    for g in ("i", "f", "z", "o"):
        gates[f"w_{g}"] = WSpec((d, d), ("embed", None), init="small")
        # "slstm_rec" (default replicated) lets a perf variant shard the
        # recurrent weights' output dim over the model axis
        gates[f"r_{g}"] = WSpec((H, hd, hd), ("ssm_heads", None, "slstm_rec"),
                                init="small")
        gates[f"b_{g}"] = WSpec((d,), (None,), init="ones" if g == "f" else "zeros")
    return {
        **gates,
        "ln": norm_specs(d, cfg.norm),
        "ffn_up": WSpec((d, d_ff), ("embed", "mlp")),
        "ffn_down": WSpec((d_ff, d), ("mlp", "embed")),
        "ffn_norm": norm_specs(d),
    }


def slstm_apply(params, x, cfg, *, state=None):
    """x: (B,S,d). state: (c,n,h,m) each (B,d)-shaped (heads folded)."""
    B, S, d = x.shape
    H, hd = slstm_dims(cfg)
    dt = x.dtype
    x = apply_norm(params["ln"], x, cfg.norm, cfg.norm_eps)
    xf = x.astype(jnp.float32)

    pre = {
        g: jnp.einsum("bsd,de->bse", xf, params[f"w_{g}"].astype(jnp.float32))
        + params[f"b_{g}"].astype(jnp.float32)
        for g in ("i", "f", "z", "o")
    }
    R = {g: params[f"r_{g}"].astype(jnp.float32) for g in ("i", "f", "z", "o")}

    if state is None:
        zeros = jnp.zeros((B, d), jnp.float32)
        c0, n0, h0 = zeros, zeros + 1e-6, zeros
        m0 = jnp.zeros((B, d), jnp.float32)
    else:
        c0, n0, h0, m0 = state

    def step(carry, inp):
        c, n, h, m = carry
        hh = h.reshape(B, H, hd)
        rec = {
            g: jnp.einsum("bhd,hde->bhe", hh, R[g]).reshape(B, d)
            for g in ("i", "f", "z", "o")
        }
        gi = inp["i"] + rec["i"]
        gf = inp["f"] + rec["f"]
        gz = jnp.tanh(inp["z"] + rec["z"])
        go = jax.nn.sigmoid(inp["o"] + rec["o"])
        m_new = jnp.maximum(jax.nn.log_sigmoid(gf) + m, gi)
        fp = jnp.exp(jax.nn.log_sigmoid(gf) + m - m_new)
        ip = jnp.exp(gi - m_new)
        c = fp * c + ip * gz
        n = fp * n + ip
        h = go * c / jnp.maximum(n, 1e-6)
        return (c, n, h, m_new), h

    xs = {g: jnp.moveaxis(v, 1, 0) for g, v in pre.items()}
    unroll = max(1, min(getattr(cfg, "slstm_unroll", 1), S))
    (cf, nf, hf, mf), hs = jax.lax.scan(step, (c0, n0, h0, m0), xs,
                                        unroll=unroll)
    y = jnp.moveaxis(hs, 0, 1).astype(dt)
    # post-FFN (GeLU, pf 4/3)
    yn = apply_norm(params["ffn_norm"], y, cfg.norm, cfg.norm_eps)
    ff = jnp.einsum("bsd,df->bsf", yn, params["ffn_up"].astype(dt))
    ff = jax.nn.gelu(ff)
    y = y + jnp.einsum("bsf,fd->bsd", ff, params["ffn_down"].astype(dt))
    return y, (cf, nf, hf, mf)
