"""Multi-head Latent Attention (DeepSeek-V2/V3).

K/V are reconstructed from a low-rank latent ``c_kv`` plus a single
shared rotary key ``k_rope``; only (c_kv, k_rope) are cached — the
defining MLA memory win (576 floats/token for deepseek-v3 vs ~32k for
vanilla MHA).

API:
  mla_project_kv(params, x, positions, cfg) -> (ckv, k_rope)
  mla_attend(params, x, positions, cfg, ckv_all, kr_all, ...) -> out
  mla_apply(...) -> (out, (ckv, k_rope))    # train / prefill convenience
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.layers.initializers import WSpec
from repro.layers.norms import apply_norm, norm_specs
from repro.layers.rope import apply_rope

NEG_INF = -2.0e38


def mla_specs(cfg):
    H = cfg.n_heads
    return {
        "w_dq": WSpec((cfg.d_model, cfg.q_lora_rank), ("embed", "mla_rank")),
        "q_norm": norm_specs(cfg.q_lora_rank),
        "w_uq": WSpec(
            (cfg.q_lora_rank, H, cfg.qk_nope_dim + cfg.qk_rope_dim),
            ("mla_rank", "heads", None),
        ),
        "w_dkv": WSpec((cfg.d_model, cfg.kv_lora_rank), ("embed", "mla_rank")),
        "kv_norm": norm_specs(cfg.kv_lora_rank),
        "w_kr": WSpec((cfg.d_model, cfg.qk_rope_dim), ("embed", None)),
        "w_uk": WSpec(
            (cfg.kv_lora_rank, H, cfg.qk_nope_dim), ("mla_rank", "heads", None)
        ),
        "w_uv": WSpec(
            (cfg.kv_lora_rank, H, cfg.v_head_dim), ("mla_rank", "heads", None)
        ),
        "w_o": WSpec((H, cfg.v_head_dim, cfg.d_model), ("heads", None, "embed")),
    }


def mla_project_kv(params, x, positions, cfg):
    dt = x.dtype
    ckv = apply_norm(
        params["kv_norm"], jnp.einsum("bsd,dr->bsr", x, params["w_dkv"].astype(dt)),
        cfg.norm, cfg.norm_eps,
    )
    k_rope = apply_rope(
        jnp.einsum("bsd,dr->bsr", x, params["w_kr"].astype(dt)), positions,
        cfg.rope_theta,
    )
    return ckv, k_rope


def mla_attend(
    params, x, *, positions, cfg,
    ckv_all, kr_all, kv_positions, kv_valid=None, causal: bool = True,
):
    dt = x.dtype
    cq = apply_norm(
        params["q_norm"], jnp.einsum("bsd,dr->bsr", x, params["w_dq"].astype(dt)),
        cfg.norm, cfg.norm_eps,
    )
    q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"].astype(dt))
    q_nope = q[..., : cfg.qk_nope_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_dim:], positions, cfg.rope_theta)

    k_nope = jnp.einsum("btr,rhk->bthk", ckv_all, params["w_uk"].astype(dt))
    v = jnp.einsum("btr,rhv->bthv", ckv_all, params["w_uv"].astype(dt))

    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    logits = (
        jnp.einsum("bshk,bthk->bhst", q_nope, k_nope)
        + jnp.einsum("bshk,btk->bhst", q_rope, kr_all)
    ).astype(jnp.float32) * scale

    qp = positions[:, :, None]
    kp = kv_positions[:, None, :]
    mask = (kp <= qp) if causal else jnp.ones_like(kp <= qp)
    if kv_valid is not None:
        mask &= kv_valid[:, None, :]
    logits = jnp.where(mask[:, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(dt)

    out = jnp.einsum("bhst,bthv->bshv", probs, v)
    return jnp.einsum("bshv,hvd->bsd", out, params["w_o"].astype(dt))


def mla_apply(params, x, *, positions, cfg):
    """Self-attention over x (train / prefill)."""
    ckv, kr = mla_project_kv(params, x, positions, cfg)
    out = mla_attend(
        params, x, positions=positions, cfg=cfg,
        ckv_all=ckv, kr_all=kr, kv_positions=positions,
    )
    return out, (ckv, kr)
