"""Token embedding and LM output head."""

from __future__ import annotations

import jax.numpy as jnp

from repro.layers.initializers import WSpec


def embed_specs(vocab: int, d_model: int):
    return {"table": WSpec((vocab, d_model), ("vocab", "embed"), init="embed", scale=0.02)}


def embed_apply(params, ids, *, scale: float = 1.0, dtype=jnp.bfloat16):
    out = params["table"][ids].astype(dtype)
    if scale != 1.0:
        out = out * jnp.asarray(scale, dtype)
    return out


def head_specs(d_model: int, vocab: int):
    return {"w": WSpec((d_model, vocab), ("embed", "vocab"), init="small")}


def head_apply(params, x, *, softcap: float = 0.0, tied_table=None):
    if tied_table is not None:
        logits = jnp.einsum("bsd,vd->bsv", x, tied_table.astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["w"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    if softcap and softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits
