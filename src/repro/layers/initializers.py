"""Declarative weight specs.

A layer declares its weights once as a pytree of ``WSpec``; the same tree
drives initialization, abstract evaluation (ShapeDtypeStruct for the
dry-run) and PartitionSpec derivation (via common.sharding.tree_pspecs).
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class WSpec:
    shape: tuple[int, ...]
    axes: tuple[Any, ...]            # logical axis names (or None), len == ndim
    init: str = "normal"             # normal | zeros | ones | embed | small
    scale: float | None = None       # stddev override for "normal"
    dtype: Any = None                # None -> param_dtype at init time

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_wspec(x) -> bool:
    return isinstance(x, WSpec)


def _std(ws: WSpec) -> float:
    if ws.scale is not None:
        return ws.scale
    if ws.init == "embed":
        return 1.0
    if ws.init == "small":
        return 0.02
    # fan-in normal
    fan_in = int(np.prod(ws.shape[:-1])) or 1
    return 1.0 / float(np.sqrt(fan_in))


def init_leaf(key, ws: WSpec, param_dtype) -> jax.Array:
    dtype = ws.dtype or param_dtype
    if ws.init == "zeros":
        return jnp.zeros(ws.shape, dtype)
    if ws.init == "ones":
        return jnp.ones(ws.shape, dtype)
    return (jax.random.normal(key, ws.shape, jnp.float32) * _std(ws)).astype(dtype)


def init_tree(key, spec_tree, param_dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=_is_wspec)
    out = [
        init_leaf(jax.random.fold_in(key, i), ws, param_dtype)
        for i, ws in enumerate(leaves)
    ]
    return jax.tree.unflatten(treedef, out)


def abstract_tree(spec_tree, param_dtype=jnp.float32, shardings=None):
    """ShapeDtypeStruct pytree; if `shardings` pytree given, attach them."""

    def one(ws, sh=None):
        dtype = ws.dtype or param_dtype
        if sh is not None:
            return jax.ShapeDtypeStruct(ws.shape, dtype, sharding=sh)
        return jax.ShapeDtypeStruct(ws.shape, dtype)

    if shardings is None:
        return jax.tree.map(one, spec_tree, is_leaf=_is_wspec)
    return jax.tree.map(one, spec_tree, shardings, is_leaf=_is_wspec)


def stack_specs(spec_tree, n: int):
    """Prepend a scanned-layers dimension (logical axis "layers")."""
    return jax.tree.map(
        lambda ws: replace(ws, shape=(n, *ws.shape), axes=("layers", *ws.axes)),
        spec_tree,
        is_leaf=_is_wspec,
    )


def spec_param_count(spec_tree) -> int:
    return sum(
        int(np.prod(ws.shape))
        for ws in jax.tree.leaves(spec_tree, is_leaf=_is_wspec)
    )


def spec_param_bytes(spec_tree, param_dtype=jnp.bfloat16) -> int:
    total = 0
    for ws in jax.tree.leaves(spec_tree, is_leaf=_is_wspec):
        dt = ws.dtype or param_dtype
        total += int(np.prod(ws.shape)) * jnp.dtype(dt).itemsize
    return total
