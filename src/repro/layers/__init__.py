"""Layer library: declarative weight specs + pure-functional apply fns."""
