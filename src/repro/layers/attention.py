"""GQA attention with sliding-window masks and logit softcapping.

The XLA path (default) is what the dry-run lowers; a Pallas flash kernel
(repro.kernels) can be selected with ``impl="pallas"`` for TPU execution
or ``impl="pallas_interpret"`` for CPU validation.

API:
  project_qkv(params, x, positions, cfg)   -> q, k, v (rope applied)
  gqa_scores(q, k, v, ...)                 -> attention output (pre-wo)
  attention_apply(params, x, ...)          -> full self-attention (train/prefill)
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.layers.initializers import WSpec
from repro.layers.rope import apply_rope

NEG_INF = -2.0e38


def attention_specs(d_model: int, n_heads: int, n_kv_heads: int, head_dim: int):
    return {
        "wq": WSpec((d_model, n_heads, head_dim), ("embed", "heads", None)),
        "wk": WSpec((d_model, n_kv_heads, head_dim), ("embed", "kv_heads", None)),
        "wv": WSpec((d_model, n_kv_heads, head_dim), ("embed", "kv_heads", None)),
        "wo": WSpec((n_heads, head_dim, d_model), ("heads", None, "embed")),
    }


def _softcap(logits, cap: float):
    if cap and cap > 0.0:
        return cap * jnp.tanh(logits / cap)
    return logits


def project_qkv(params, x, positions, cfg):
    """Project and (optionally) rope q/k.  x: (B,S,D) -> q (B,S,H,hd), k/v (B,S,K,hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def output_proj(params, out, dtype):
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))


def gqa_scores(
    q, k, v, *,
    q_positions, kv_positions,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    kv_valid: Optional[jax.Array] = None,   # (B, T) bool — cache validity
    scale: Optional[float] = None,
    softmax_dtype=jnp.float32,
):
    """Grouped-query attention core.

    q: (B, S, H, D); k, v: (B, T, K, D) with H = K * G.  K/V are repeated
    to the full H head dim so the scores tensor (B, H, S, T) carries the
    tensor-parallel head sharding — with the grouped (B, K, G, S, T)
    layout XLA cannot shard K*G and replicates the quadratic scores on
    every model rank (measured: 16x temp memory on the dry-run).
    """
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    logits = jnp.einsum("bshd,bthd->bhst", q, k).astype(softmax_dtype) * scale
    logits = _softcap(logits, softcap)

    qp = q_positions[:, :, None]                      # (B, S, 1)
    kp = kv_positions[:, None, :]                     # (B, 1, T)
    mask = jnp.ones((B, S, T), dtype=bool)
    if causal:
        mask &= kp <= qp
    if window and window > 0:
        mask &= kp > qp - window
    if kv_valid is not None:
        mask &= kv_valid[:, None, :]
    neg = jnp.asarray(NEG_INF if softmax_dtype == jnp.float32 else -3e38,
                      softmax_dtype) if softmax_dtype == jnp.float32 else \
        jnp.asarray(jnp.finfo(softmax_dtype).min, softmax_dtype)
    logits = jnp.where(mask[:, None, :, :], logits, neg)

    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def attention_apply(
    params, x, *,
    positions,
    cfg,
    local: bool = False,
    causal: bool = True,
    cross_kv=None,            # (k, v) from an encoder for cross-attention
    cross_positions=None,
    impl: str = "xla",
    constrain_kv=None,        # SP: pin k/v replicated over model so the
                              # scores keep the seq sharding (see §Perf)
    softmax_dtype=jnp.float32,
):
    """Self- (or cross-) attention over the given sequence (train / prefill).

    Returns (out, (k, v)) — the freshly projected k/v for cache insertion.
    """
    if cross_kv is not None:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
        if cfg.use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
        k, v = cross_kv
        out = gqa_scores(
            q, k, v, q_positions=positions, kv_positions=cross_positions,
            causal=False, window=0, softcap=cfg.attn_logit_softcap,
        )
        return output_proj(params, out, x.dtype), (k, v)

    q, k, v = project_qkv(params, x, positions, cfg)
    if constrain_kv is not None:
        k = constrain_kv(k)
        v = constrain_kv(v)
    window = cfg.sliding_window if local else 0

    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops

        out = kops.flash_attention(
            q, k, v,
            causal=causal, window=window, softcap=cfg.attn_logit_softcap,
            interpret=(impl == "pallas_interpret"),
        )
    else:
        out = gqa_scores(
            q, k, v,
            q_positions=positions, kv_positions=positions,
            causal=causal, window=window, softcap=cfg.attn_logit_softcap,
            softmax_dtype=softmax_dtype,
        )
    return output_proj(params, out, x.dtype), (k, v)


def cross_kv_project(params, enc_out, cfg):
    """Project encoder output into cross-attention K/V once (cached)."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(enc_out.dtype))
    return k, v


def decode_attention_shardmap(q, k_cache, v_cache, lengths, *, mesh, rules,
                              window: int = 0, softcap: float = 0.0):
    """Distributed partial-softmax decode attention under shard_map.

    q: (B, 1, H, D) batch-sharded; cache: (B, T, K, D) batch-sharded over
    the data axes and seq-sharded over 'model'.  Each chip computes
    logits/softmax partials over its local seq tile; a pmax + two psums
    (scalars and (B,H,D)) combine — the cache never moves.  This is the
    flash-decoding communication pattern expressed manually because
    GSPMD keeps resolving the q-heads/cache-seq sharding conflict by
    all-gathering the cache (measured: 270 GB/step on llama3-405b).
    """
    import math as _math

    from repro.common.sharding import spec_for
    from repro.layers.moe import shard_map_compat

    B, _, H, D = q.shape
    T, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = 1.0 / _math.sqrt(D)
    # q follows the CACHE's batch sharding (pjit auto-reshards q if the
    # activation rules keep it replicated)
    spec_q = spec_for(q.shape, ("cache_batch", None, None, None), rules, mesh)
    spec_c = spec_for(k_cache.shape,
                      ("cache_batch", "cache_seq", None, None), rules, mesh)
    spec_l = spec_for(lengths.shape, ("cache_batch",), rules, mesh)
    t_entry = spec_c[1]
    seq_axes = (() if t_entry is None else
                (t_entry if isinstance(t_entry, tuple) else (t_entry,)))

    def f(q_l, k_l, v_l, len_l):
        B_loc, _, _, _ = q_l.shape
        T_loc = k_l.shape[1]
        t_off = jnp.zeros((), jnp.int32)
        idx = 0
        for a in seq_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        t_off = (idx * T_loc) if seq_axes else 0
        kv_pos = t_off + jnp.arange(T_loc, dtype=jnp.int32)       # (T_loc,)
        if G > 1:
            k_rep = jnp.repeat(k_l, G, axis=2)
            v_rep = jnp.repeat(v_l, G, axis=2)
        else:
            k_rep, v_rep = k_l, v_l
        logits = jnp.einsum("bshd,bthd->bhst", q_l,
                            k_rep.astype(q_l.dtype)).astype(jnp.float32) * scale
        if softcap and softcap > 0.0:
            logits = softcap * jnp.tanh(logits / softcap)
        pos = len_l[:, None]                                       # (B,1)
        valid = kv_pos[None, :] < (len_l + 1)[:, None]             # (B,T_loc)
        if window and window > 0:
            valid &= kv_pos[None, :] > pos - window
        logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
        m_loc = jnp.max(logits, axis=-1)                           # (B,H,1)
        if seq_axes:
            m = jax.lax.pmax(m_loc, seq_axes if len(seq_axes) > 1
                             else seq_axes[0])
        else:
            m = m_loc
        safe_m = jnp.where(m > NEG_INF / 2, m, 0.0)
        p = jnp.exp(logits - safe_m[..., None])
        p = jnp.where(valid[:, None, None, :], p, 0.0)
        s_loc = jnp.sum(p, axis=-1)                                # (B,H,1)
        o_loc = jnp.einsum("bhst,bthd->bshd", p.astype(q_l.dtype), v_rep)
        if seq_axes:
            ax = seq_axes if len(seq_axes) > 1 else seq_axes[0]
            s = jax.lax.psum(s_loc, ax)
            o = jax.lax.psum(o_loc.astype(jnp.float32), ax)
        else:
            s, o = s_loc, o_loc.astype(jnp.float32)
        out = o / jnp.maximum(s, 1e-30).transpose(0, 2, 1)[..., None]
        return out.astype(q_l.dtype)

    return shard_map_compat(
        f, mesh,
        in_specs=(spec_q, spec_c, spec_c, spec_l),
        out_specs=spec_q,
    )(q, k_cache, v_cache, lengths)


def cache_insert(cache_arr, new_val, lengths, *, mode: str = "scatter",
                 mesh=None, rules=None):
    """Insert new_val (B, 1, ...) into cache (B, T, ...) at per-batch
    position `lengths`.

    mode="scatter": gather/scatter update — natural but hostile to a
    seq-sharded cache (GSPMD replicates the operand: "involuntary full
    rematerialization", measured as a full-cache all-gather per layer).
    mode="blend": one-hot masked rewrite — elementwise, but the traffic
    model charges a full cache rewrite (measured worse; kept as a
    refuted-hypothesis record, see EXPERIMENTS.md §Perf).
    mode="shard": shard_map update — each chip scatters into its local
    (batch, seq) tile only when the position falls inside it; exactly
    partitioned, zero collectives.
    """
    B, T = cache_arr.shape[:2]
    if mode == "shard" and mesh is not None:
        return _cache_insert_shardmap(cache_arr, new_val, lengths, mesh, rules)
    if mode == "blend":
        onehot = (jnp.arange(T, dtype=jnp.int32)[None, :]
                  == lengths[:, None])                       # (B, T)
        oh = onehot.reshape(B, T, *([1] * (cache_arr.ndim - 2)))
        newb = new_val[:, :1].astype(cache_arr.dtype)        # (B,1,...)
        return jnp.where(oh, newb, cache_arr)
    return cache_arr.at[jnp.arange(B), lengths].set(
        new_val[:, 0].astype(cache_arr.dtype))


def paged_cache_insert(pages, new_val, block_tables, lengths):
    """Insert new_val (B, 1, ...) into a paged cache (n_pages,
    page_size, ...) at per-sequence position ``lengths``, resolving the
    owning page through ``block_tables`` (B, n_max).

    Live sequences never share pages, so the batched scatter indices
    are unique across rows; rows whose table points at a dummy page
    (dead decode rows) collide only with each other, on a page no
    sequence reads.
    """
    ps = pages.shape[1]
    B = new_val.shape[0]
    n_max = block_tables.shape[1]
    page = block_tables[jnp.arange(B), jnp.clip(lengths // ps, 0, n_max - 1)]
    off = lengths % ps
    return pages.at[page, off].set(new_val[:, 0].astype(pages.dtype))


def paged_gather(pages, block_tables):
    """Materialize each sequence's pages contiguously: (n_pages, ps,
    ...) + tables (B, n_max) -> (B, n_max*ps, ...) — the XLA-path view
    the paged Pallas kernel avoids building."""
    B, n_max = block_tables.shape
    ps = pages.shape[1]
    return pages[block_tables].reshape(B, n_max * ps, *pages.shape[2:])


def _cache_insert_shardmap(cache_arr, new_val, lengths, mesh, rules):
    import numpy as np

    from repro.common.sharding import spec_for
    from repro.layers.moe import shard_map_compat

    nd = cache_arr.ndim
    axes_c = ("cache_batch", "cache_seq") + (None,) * (nd - 2)
    spec_c = spec_for(cache_arr.shape, axes_c, rules, mesh)
    axes_n = ("cache_batch", None) + (None,) * (nd - 2)
    spec_n = spec_for(new_val.shape, axes_n, rules, mesh)
    spec_l = spec_for(lengths.shape, ("cache_batch",), rules, mesh)
    t_entry = spec_c[1]

    def f(c, nv, ln):
        B_loc, T_loc = c.shape[:2]
        t_off = 0
        if t_entry is not None:
            names = t_entry if isinstance(t_entry, tuple) else (t_entry,)
            idx = 0
            for a in names:
                idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
            t_off = idx * T_loc
        pos = ln - t_off                                     # (B_loc,)
        inb = (pos >= 0) & (pos < T_loc)
        posc = jnp.clip(pos, 0, T_loc - 1)
        rows = jnp.arange(B_loc)
        old = c[rows, posc]
        mask = inb.reshape(-1, *([1] * (nd - 2)))
        new_rows = jnp.where(mask, nv[:, 0].astype(c.dtype), old)
        return c.at[rows, posc].set(new_rows)

    return shard_map_compat(
        f, mesh, in_specs=(spec_c, spec_n, spec_l), out_specs=spec_c,
    )(cache_arr, new_val, lengths)
