"""Scan-over-layers machinery.

Stacks of homogeneous blocks are scanned so the HLO stays O(1) in depth —
this is what makes 126-layer × 512-device programs compile on a CPU host.
Heterogeneous architectures are sequences of homogeneous *stages*.

``scan_stack(fn, stacked_params, h, xs=None)`` where
``fn(layer_params, h, x_l) -> (h', y_l)``; ``xs``/``ys`` carry per-layer
state (KV caches in decode, collected caches in prefill).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def remat_policy(name: str):
    if name == "none":
        return None
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable  # "full"


def scan_stack(fn, stacked_params, h, xs=None, *, remat: str = "full", unroll=1):
    """Scan `fn` over the leading (layer) axis of `stacked_params`.

    fn(layer_params, h, x_l) -> (h_new, y_l);  y_l may be None.
    Returns (h_final, ys) with ys stacked on a leading layer axis.
    """

    def body(carry, scanned):
        lp, x_l = scanned
        h_new, y_l = fn(lp, carry, x_l)
        return h_new, y_l

    n = jax.tree.leaves(stacked_params)[0].shape[0]
    if unroll is True:
        unroll = n
    unroll = max(1, min(int(unroll), n))
    if remat != "none":
        # prevent_cse=False is safe (and faster) only under an actual scan
        # loop; with unrolled bodies CSE would silently defeat remat.
        body = jax.checkpoint(body, policy=remat_policy(remat),
                              prevent_cse=(unroll > 1))
    if xs is None:
        xs_t = (stacked_params, _nones(n))
    else:
        xs_t = (stacked_params, xs)
    h_final, ys = jax.lax.scan(body, h, xs_t, unroll=unroll)
    return h_final, ys


def _nones(n):
    return jnp.zeros((n, 0), jnp.float32)  # zero-width placeholder, scans cheaply
