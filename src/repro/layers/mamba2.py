"""Mamba2 (State-Space Duality) block.

Chunkwise-parallel SSD for train/prefill (linear in sequence length) and
an O(1) recurrent step for decode.  ``ssd_recurrent_ref`` is the naive
per-step oracle used by tests.  A Pallas kernel for the intra-chunk part
lives in repro.kernels.ssd_scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers.initializers import WSpec
from repro.layers.norms import apply_norm, norm_specs


def mamba2_dims(cfg):
    d_in = cfg.mamba_expand * cfg.d_model
    n_heads = d_in // cfg.mamba_head_dim
    return d_in, n_heads, cfg.ssm_state


def mamba2_specs(cfg):
    d_in, H, N = mamba2_dims(cfg)
    W = cfg.mamba_conv_width
    return {
        "wz": WSpec((cfg.d_model, d_in), ("embed", "ssm_inner")),
        "wx": WSpec((cfg.d_model, d_in), ("embed", "ssm_inner")),
        "wB": WSpec((cfg.d_model, N), ("embed", "ssm_state")),
        "wC": WSpec((cfg.d_model, N), ("embed", "ssm_state")),
        "wdt": WSpec((cfg.d_model, H), ("embed", "ssm_heads")),
        "conv_x": WSpec((W, d_in), (None, "ssm_inner")),
        "conv_B": WSpec((W, N), (None, "ssm_state")),
        "conv_C": WSpec((W, N), (None, "ssm_state")),
        "A_log": WSpec((H,), ("ssm_heads",), init="zeros"),
        "dt_bias": WSpec((H,), ("ssm_heads",), init="zeros"),
        "D_skip": WSpec((H,), ("ssm_heads",), init="ones"),
        "out_norm": norm_specs(d_in),
        "w_out": WSpec((d_in, cfg.d_model), ("ssm_inner", "embed")),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv.  x: (B, S, C), w: (W, C).

    With `state` (B, W-1, C) the conv continues from cached history and the
    new state is returned.
    """
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(W))
    new_state = xp[:, -(W - 1) :, :]
    return out, new_state


def _ssd_chunked(xh, Bm, Cm, dt, A_log, D_skip, chunk: int, initial_state=None):
    """Chunkwise SSD.

    xh: (B, S, H, P); Bm/Cm: (B, S, N); dt: (B, S, H) (post-softplus).
    Returns (y: (B,S,H,P), final_state: (B,H,N,P)).
    """
    Bsz, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    if S % L:  # pad tail: dt=0 -> decay 1, update 0 (state-neutral)
        pad = L - S % L
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        out, final = _ssd_chunked(xh, Bm, Cm, dt, A_log, D_skip, chunk,
                                  initial_state)
        return out[:, :S], final
    nc = S // L

    a = -jnp.exp(A_log.astype(jnp.float32))            # (H,) negative
    dA = dt.astype(jnp.float32) * a                     # (B,S,H) log decay <=0

    xc = xh.reshape(Bsz, nc, L, H, Pd).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, L, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, L, N).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, L, H).astype(jnp.float32)
    dAc = dA.reshape(Bsz, nc, L, H)

    cum = jnp.cumsum(dAc, axis=2)                       # (B,nc,L,H)

    # intra-chunk: scores[s->t] = C_t.B_s * exp(cum_t - cum_s) * dt_s, s<=t
    G = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)           # (B,nc,L,L) t=l, s=m
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (B,nc,t,s,H)
    causal = jnp.tril(jnp.ones((L, L), bool))
    M = jnp.where(causal[None, None, :, :, None], G[..., None] * decay, 0.0)
    xdt = xc * dtc[..., None]                            # (B,nc,L,H,P)
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", M, xdt)

    # per-chunk end state: S_c = sum_s exp(cum_L - cum_s) dt_s B_s x_s
    w_end = jnp.exp(cum[:, :, -1:, :] - cum)             # (B,nc,L,H)
    S_loc = jnp.einsum("bcln,bclh,bclhp->bchnp", Bc, w_end * dtc, xc)

    # inter-chunk recurrence over c: S_run = S_prev * Lam_c + S_loc_c
    Lam = jnp.exp(cum[:, :, -1, :])                      # (B,nc,H)

    def combine(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return a1 * a2, s1 * a2[..., None, None] + s2

    Lam_s = jnp.moveaxis(Lam, 1, 0)                      # (nc,B,H)
    S_s = jnp.moveaxis(S_loc, 1, 0)                      # (nc,B,H,N,P)
    if initial_state is not None:
        init = initial_state.astype(jnp.float32)
        Lam_s = jnp.concatenate([jnp.ones_like(Lam_s[:1]), Lam_s], 0)
        S_s = jnp.concatenate([init[None], S_s], 0)
    accA, accS = jax.lax.associative_scan(combine, (Lam_s, S_s), axis=0)
    if initial_state is not None:
        accS_states = accS                                # (nc+1,...) state AFTER chunk c-1
        S_before = accS_states[:-1]
        final = accS_states[-1]
    else:
        S_before = jnp.concatenate([jnp.zeros_like(accS[:1]), accS[:-1]], 0)
        final = accS[-1]
    S_before = jnp.moveaxis(S_before, 0, 1)              # (B,nc,H,N,P)

    # inter-chunk output: y_t += C_t . S_before * exp(cum_t)
    y_inter = jnp.einsum(
        "bcln,bchnp,bclh->bclhp", Cc, S_before, jnp.exp(cum)
    )

    y = y_intra + y_inter + xc * D_skip.astype(jnp.float32)[None, None, None, :, None]
    return y.reshape(Bsz, S, H, Pd).astype(xh.dtype), final


def ssd_recurrent_ref(xh, Bm, Cm, dt, A_log, D_skip, initial_state=None):
    """Naive per-step oracle: s = s*exp(dt*a) + dt * B (x) ; y = C.s + D*x."""
    Bsz, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    a = -jnp.exp(A_log.astype(jnp.float32))

    def step(s, inp):
        x_t, B_t, C_t, dt_t = inp
        decay = jnp.exp(dt_t * a)                        # (B,H)
        upd = jnp.einsum("bn,bh,bhp->bhnp", B_t, dt_t, x_t)
        s = s * decay[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", C_t, s) + x_t * D_skip[None, :, None]
        return s, y

    s0 = (jnp.zeros((Bsz, H, N, Pd), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    xs = (
        jnp.moveaxis(xh.astype(jnp.float32), 1, 0),
        jnp.moveaxis(Bm.astype(jnp.float32), 1, 0),
        jnp.moveaxis(Cm.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
    )
    final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(xh.dtype), final


def mamba2_apply(params, x, cfg, *, state=None, impl: str = "chunked"):
    """Full block body.  x: (B, S, d_model).

    state: None (fresh) or dict(ssm=(B,H,N,P), conv_x/conv_B/conv_C).
    Returns (y, new_state).
    """
    d_in, H, N = mamba2_dims(cfg)
    dt_ = x.dtype
    z = jnp.einsum("bsd,de->bse", x, params["wz"].astype(dt_))
    xr = jnp.einsum("bsd,de->bse", x, params["wx"].astype(dt_))
    Br = jnp.einsum("bsd,dn->bsn", x, params["wB"].astype(dt_))
    Cr = jnp.einsum("bsd,dn->bsn", x, params["wC"].astype(dt_))
    dtl = jnp.einsum("bsd,dh->bsh", x, params["wdt"].astype(dt_))

    cs = state or {}
    xc, ns_x = _causal_conv(xr, params["conv_x"].astype(dt_), cs.get("conv_x"))
    Bc, ns_B = _causal_conv(Br, params["conv_B"].astype(dt_), cs.get("conv_B"))
    Cc, ns_C = _causal_conv(Cr, params["conv_C"].astype(dt_), cs.get("conv_C"))
    xc, Bc, Cc = jax.nn.silu(xc), jax.nn.silu(Bc), jax.nn.silu(Cc)

    dt_soft = jax.nn.softplus(
        dtl.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )
    xh = xc.reshape(*xc.shape[:2], H, cfg.mamba_head_dim)

    init_ssm = cs.get("ssm")
    if impl == "recurrent" or x.shape[1] == 1:
        y, final = ssd_recurrent_ref(
            xh, Bc, Cc, dt_soft, params["A_log"], params["D_skip"].astype(jnp.float32),
            initial_state=init_ssm,
        )
    else:
        y, final = _ssd_chunked(
            xh, Bc, Cc, dt_soft, params["A_log"], params["D_skip"].astype(jnp.float32),
            cfg.mamba_chunk, initial_state=init_ssm,
        )

    y = y.reshape(*x.shape[:2], d_in)
    y = apply_norm(params["out_norm"], y * jax.nn.silu(z), cfg.norm, cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(dt_))
    new_state = {"ssm": final, "conv_x": ns_x, "conv_B": ns_B, "conv_C": ns_C}
    return out, new_state
