"""Mixture-of-Experts.

Two execution paths sharing one weight layout:

* ``dense`` — every expert runs on every token, masked by top-k gates.
  O(E/k) FLOP overhead; used for tiny smoke configs and as the oracle in
  tests.
* ``ep`` — expert-parallel shard_map path.  Tokens stay batch-sharded and
  replicated over the ``model`` axis; each model-rank scatters its local
  experts' tokens into a capacity-bounded buffer (sort-based dispatch),
  runs the expert FFNs, scatters results back, and a psum over ``model``
  combines contributions.  Expert weights are EP-sharded over ``model``
  and FSDP-sharded over (pod, data) — the dp shards are all-gathered
  inside the shard_map (ZeRO-3 style).

Expert counts that do not divide the model axis are padded with
zero-initialized, never-routed experts (granite: 40 -> 48).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.layers.initializers import WSpec
from repro.layers.mlp import activation, mlp_apply, mlp_specs


def shard_map_compat(f, mesh, in_specs, out_specs):
    # jax >= 0.5 exposes jax.shard_map (check_vma kwarg); older releases
    # raise AttributeError on the lookup and ship it under experimental
    try:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                             check_vma=False)
    except (AttributeError, TypeError):
        from jax.experimental.shard_map import shard_map

        return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                         check_rep=False)


def padded_experts(cfg) -> int:
    return cfg.expert_pad_to or cfg.n_experts


def moe_specs(cfg):
    E = padded_experts(cfg)
    f = cfg.moe_d_ff or cfg.d_ff
    specs = {
        "router": WSpec((cfg.d_model, cfg.n_experts), (None, None), init="small"),
        "wi_gate": WSpec((E, cfg.d_model, f), ("experts", "embed", "expert_mlp")),
        "wi_up": WSpec((E, cfg.d_model, f), ("experts", "embed", "expert_mlp")),
        "wo": WSpec((E, f, cfg.d_model), ("experts", "expert_mlp", "embed")),
    }
    if cfg.n_shared_experts:
        specs["shared"] = mlp_specs(cfg.d_model, f * cfg.n_shared_experts)
    return specs


def _route(tokens, router, cfg):
    """tokens: (T, D) -> (gates (T,k), idx (T,k), aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", tokens.astype(jnp.float32),
                        router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.experts_top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch-style)
    frac = jnp.mean(
        jax.nn.one_hot(idx, cfg.n_experts, dtype=jnp.float32), axis=(0, 1)
    )
    imp = jnp.mean(probs, axis=0)
    aux = cfg.n_experts * jnp.sum(frac * imp)
    return gates, idx, aux


def moe_apply_dense(params, x, cfg):
    """Oracle path: run all experts, combine with top-k gate weights."""
    B, S, D = x.shape
    E = padded_experts(cfg)
    tokens = x.reshape(-1, D)
    gates, idx, aux = _route(tokens, params["router"], cfg)
    comb = jnp.zeros((tokens.shape[0], E), jnp.float32)
    comb = jnp.sum(
        jax.nn.one_hot(idx, E, dtype=jnp.float32) * gates[..., None], axis=1
    )
    act = activation(cfg.act_fn)
    h_g = jnp.einsum("td,edf->etf", tokens, params["wi_gate"].astype(x.dtype))
    h_u = jnp.einsum("td,edf->etf", tokens, params["wi_up"].astype(x.dtype))
    h = act(h_g) * h_u
    y_e = jnp.einsum("etf,efd->etd", h, params["wo"].astype(x.dtype))
    y = jnp.einsum("etd,te->td", y_e.astype(jnp.float32), comb).astype(x.dtype)
    y = y.reshape(B, S, D)
    if cfg.n_shared_experts:
        y = y + mlp_apply(params["shared"], x, cfg.act_fn)
    return y, aux


def _dp_axes(mesh, batch: int) -> tuple[str, ...]:
    """Data axes usable for the token shard (must divide batch)."""
    axes = []
    prod = 1
    for a in ("pod", "data"):
        if a in mesh.shape and batch % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    return tuple(axes)


def moe_apply_ep(params, x, cfg, mesh, *, capacity_factor: float = 1.25,
                 ep_axis: str = "model"):
    """Expert-parallel path (see module docstring)."""
    B, S, D = x.shape
    E = padded_experts(cfg)
    k = cfg.experts_top_k
    if ep_axis not in mesh.shape or E % mesh.shape[ep_axis] != 0:
        return moe_apply_dense(params, x, cfg)
    ep_size = mesh.shape[ep_axis]
    E_loc = E // ep_size
    dp = _dp_axes(mesh, B)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    T_loc = (B // dp_size) * S
    C = max(1, int(math.ceil(T_loc * k * capacity_factor / cfg.n_experts)))

    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    x_spec = P(dp_spec, None, None)
    # expert weights: EP over model, FSDP over dp when divisible
    fsdp = dp_spec if (dp and D % dp_size == 0) else None
    w_spec = P(ep_axis, fsdp, None)
    wo_spec = P(ep_axis, None, fsdp)

    def f(x_loc, router, wig, wiu, wo):
        if fsdp is not None:
            wig = jax.lax.all_gather(wig, dp_spec, axis=1, tiled=True)
            wiu = jax.lax.all_gather(wiu, dp_spec, axis=1, tiled=True)
            wo = jax.lax.all_gather(wo, dp_spec, axis=2, tiled=True)
        tokens = x_loc.reshape(-1, D)
        T = tokens.shape[0]
        gates, idx, aux = _route(tokens, router, cfg)

        flat_e = idx.reshape(-1)                       # (T*k,)
        flat_g = gates.reshape(-1)
        order = jnp.argsort(flat_e)                    # stable
        se = flat_e[order]
        tok_ids = order // k
        sg = flat_g[order]
        starts = jnp.searchsorted(se, jnp.arange(E), side="left")
        pos = jnp.arange(T * k) - starts[se]
        e0 = jax.lax.axis_index(ep_axis) * E_loc
        local = (se >= e0) & (se < e0 + E_loc) & (pos < C)
        slot = jnp.where(local, (se - e0) * C + pos, E_loc * C)

        gathered = tokens[tok_ids] * local[:, None].astype(tokens.dtype)
        buf = jnp.zeros((E_loc * C + 1, D), x_loc.dtype).at[slot].set(gathered)
        bufe = buf[:-1].reshape(E_loc, C, D)

        act = activation(cfg.act_fn)
        h = act(jnp.einsum("ecd,edf->ecf", bufe, wig.astype(x_loc.dtype)))
        h = h * jnp.einsum("ecd,edf->ecf", bufe, wiu.astype(x_loc.dtype))
        out = jnp.einsum("ecf,efd->ecd", h, wo.astype(x_loc.dtype))
        out_flat = out.reshape(E_loc * C, D)

        contrib = out_flat[jnp.where(local, slot, 0)]
        contrib = contrib * (sg * local).astype(contrib.dtype)[:, None]
        y = jnp.zeros((T, D), x_loc.dtype).at[tok_ids].add(contrib)
        y = jax.lax.psum(y, ep_axis)
        # aux identical on every ep rank (same tokens) — mean over dp shards
        if dp:
            aux = jax.lax.pmean(aux, dp_spec)
        return y.reshape(x_loc.shape), aux

    y, aux = shard_map_compat(
        f, mesh,
        in_specs=(x_spec, P(None, None), w_spec, w_spec, wo_spec),
        out_specs=(x_spec, P()),
    )(x, params["router"], params["wi_gate"], params["wi_up"], params["wo"])

    if cfg.n_shared_experts:
        y = y + mlp_apply(params["shared"], x, cfg.act_fn)
    return y, aux


def moe_apply(params, x, cfg, mesh=None, impl: str = "dense"):
    if impl == "ep" and mesh is not None:
        return moe_apply_ep(params, x, cfg, mesh)
    return moe_apply_dense(params, x, cfg)
