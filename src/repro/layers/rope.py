"""Rotary position embeddings (half-rotation layout, LLaMA-style)."""

from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(dim: int, theta: float):
    exponent = jnp.arange(0, dim, 2, dtype=jnp.float32) / dim
    return 1.0 / (theta ** exponent)  # (dim/2,)


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim) or (..., seq, head_dim); positions: (..., seq)."""
    dim = x.shape[-1]
    inv = rope_freqs(dim, theta)                       # (dim/2,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., seq, dim/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == positions.ndim + 2:                   # heads axis present
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
