"""Gated MLP (SwiGLU / GeGLU)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers.initializers import WSpec


def mlp_specs(d_model: int, d_ff: int):
    return {
        "wi_gate": WSpec((d_model, d_ff), ("embed", "mlp")),
        "wi_up": WSpec((d_model, d_ff), ("embed", "mlp")),
        "wo": WSpec((d_ff, d_model), ("mlp", "embed")),
    }


def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
    }[name]


def mlp_apply(params, x, act_fn: str = "silu"):
    act = activation(act_fn)
    g = jnp.einsum("bsd,df->bsf", x, params["wi_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, params["wi_up"].astype(x.dtype))
    h = act(g) * u
    return jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(x.dtype))
