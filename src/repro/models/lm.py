"""Decoder-only LM covering all assigned text families via *stages*.

A model is: embedding -> [stage_0 ... stage_k] -> final norm -> head.
Each stage is a scan over homogeneous blocks; heterogeneous architectures
(gemma2 local/global pairs, deepseek dense->MoE, zamba2 mamba+shared-attn
superblocks, xLSTM 7:1 groups) become short sequences of stages, keeping
the HLO O(1) in depth.

Modes: "train" (no cache), "prefill" (fills caches), "decode" (one token,
reads+updates caches).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.layers import attention as attn
from repro.layers import mamba2 as m2
from repro.layers import mla as mla_lib
from repro.layers import moe as moe_lib
from repro.layers import xlstm as xl
from repro.layers.embedding import embed_apply, embed_specs, head_apply, head_specs
from repro.layers.initializers import WSpec, stack_specs
from repro.layers.mlp import mlp_apply, mlp_specs
from repro.layers.norms import apply_norm, norm_specs
from repro.layers.stack import scan_stack


# ---------------------------------------------------------------------------
# block spec builders
# ---------------------------------------------------------------------------

def _attn_block_specs(cfg, use_moe: bool, post_norm: bool):
    d = cfg.d_model
    specs = {
        "ln_attn": norm_specs(d, cfg.norm),
        "attn": attn.attention_specs(d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim),
        "ln_mlp": norm_specs(d, cfg.norm),
    }
    if use_moe:
        specs["moe"] = moe_lib.moe_specs(cfg)
    else:
        specs["mlp"] = mlp_specs(d, cfg.d_ff)
    if post_norm:
        specs["ln_attn_post"] = norm_specs(d, cfg.norm)
        specs["ln_mlp_post"] = norm_specs(d, cfg.norm)
    return specs


def _mla_block_specs(cfg, use_moe: bool):
    d = cfg.d_model
    specs = {
        "ln_attn": norm_specs(d, cfg.norm),
        "attn": mla_lib.mla_specs(cfg),
        "ln_mlp": norm_specs(d, cfg.norm),
    }
    if use_moe:
        specs["moe"] = moe_lib.moe_specs(cfg)
    else:
        specs["mlp"] = mlp_specs(d, cfg.dense_d_ff or cfg.d_ff)
    return specs


def _mamba_block_specs(cfg):
    return {"ln": norm_specs(cfg.d_model, cfg.norm), "mamba": m2.mamba2_specs(cfg)}


def _shared_attn_specs(cfg):
    d = cfg.d_model
    return {
        "ln_attn": norm_specs(d, cfg.norm),
        "attn": attn.attention_specs(d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim),
        "ln_mlp": norm_specs(d, cfg.norm),
        "mlp": mlp_specs(d, cfg.shared_attn_d_ff or cfg.d_ff),
    }


# ---------------------------------------------------------------------------
# block bodies
# ---------------------------------------------------------------------------

def _constrain_kv_fn(ctx):
    """SP helper: replicate k/v over the model axis (an explicit small
    gather) so q keeps the seq sharding through the scores einsum —
    without this GSPMD resolves the double-use of the model axis by
    replicating the quadratic scores (§Perf)."""
    if not ctx.get("attn_sp") or ctx.get("mesh") is None:
        return None
    from repro.common.sharding import spec_for

    def constrain(kv):
        spec = spec_for(kv.shape, ("batch", None, None, None),
                        ctx["rules"], ctx["mesh"])
        return jax.lax.with_sharding_constraint(
            kv, jax.sharding.NamedSharding(ctx["mesh"], spec))

    return constrain


def _apply_attn_sub(p, h, cache, ctx, cfg, *, local: bool, post_norm: bool):
    """Norm + attention + residual (+post-norm). Returns (h, new_cache)."""
    x = apply_norm(p["ln_attn"], h, cfg.norm, cfg.norm_eps)
    ckv = _constrain_kv_fn(ctx)
    smd = ctx.get("softmax_dtype", jnp.float32)
    if ctx["mode"] == "train":
        y, _ = attn.attention_apply(
            p["attn"], x, positions=ctx["positions"], cfg=cfg, local=local,
            impl=ctx["attn_impl"], constrain_kv=ckv, softmax_dtype=smd,
        )
        new_cache = cache
    elif ctx["mode"] == "prefill":
        S = x.shape[1]
        y, (k, v) = attn.attention_apply(
            p["attn"], x, positions=ctx["positions"], cfg=cfg, local=local,
            constrain_kv=ckv, softmax_dtype=smd,
        )
        new_cache = {
            "k": cache["k"].at[:, :S].set(k.astype(cache["k"].dtype)),
            "v": cache["v"].at[:, :S].set(v.astype(cache["v"].dtype)),
        }
    else:  # decode: single token at per-batch position `lengths`
        B = x.shape[0]
        q_pos = ctx["positions"]
        lengths = ctx["lengths"]
        q, k_new, v_new = attn.project_qkv(p["attn"], x, q_pos, cfg)
        if ctx.get("decode_attn") == "gatherq" and ctx["mesh"] is not None:
            # Release q's head sharding (a ~MB gather) so the seq-sharded
            # cache is consumed by distributed partial-softmax attention
            # instead of being all-gathered every layer (§Perf).
            from repro.common.sharding import spec_for

            spec = spec_for(q.shape, ("batch", None, None, None),
                            ctx["rules"], ctx["mesh"])
            q = jax.lax.with_sharding_constraint(
                q, jax.sharding.NamedSharding(ctx["mesh"], spec))
        if ctx.get("cache_layout") == "paged":
            return _paged_attn_decode(p, h, x, cache, q, k_new, v_new,
                                      ctx, cfg, local=local,
                                      post_norm=post_norm)
        mode = ctx.get("cache_update", "scatter")
        k_cache = attn.cache_insert(cache["k"], k_new, lengths, mode=mode,
                                    mesh=ctx["mesh"], rules=ctx.get("rules"))
        v_cache = attn.cache_insert(cache["v"], v_new, lengths, mode=mode,
                                    mesh=ctx["mesh"], rules=ctx.get("rules"))
        if ctx.get("decode_attn") == "shardmap" and ctx["mesh"] is not None:
            out = attn.decode_attention_shardmap(
                q, k_cache, v_cache, lengths,
                mesh=ctx["mesh"], rules=ctx["rules"],
                window=(cfg.sliding_window if local else 0),
                softcap=cfg.attn_logit_softcap,
            )
        else:
            T = k_cache.shape[1]
            kv_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
            kv_valid = kv_pos < (lengths + 1)[:, None]
            out = attn.gqa_scores(
                q, k_cache.astype(x.dtype), v_cache.astype(x.dtype),
                q_positions=q_pos, kv_positions=kv_pos,
                causal=True, window=(cfg.sliding_window if local else 0),
                softcap=cfg.attn_logit_softcap, kv_valid=kv_valid,
            )
        y = attn.output_proj(p["attn"], out, x.dtype)
        new_cache = {"k": k_cache, "v": v_cache}
    if post_norm:
        y = apply_norm(p["ln_attn_post"], y, cfg.norm, cfg.norm_eps)
    return h + y, new_cache


def _paged_attn_decode(p, h, x, cache, q, k_new, v_new, ctx, cfg, *,
                       local: bool, post_norm: bool):
    """Decode step against a paged KV cache: cache leaves are global
    page pools (n_pages, page_size, K, D); ``ctx["block_tables"]``
    (B, n_max) names each row's pages.  ``ctx["paged_attn"]`` picks the
    attention path: "pallas"/"pallas_interpret" run the batched paged
    kernel; "xla" (default, and any local/windowed layer — the kernel
    has no window support) gathers the owned pages and reuses
    gqa_scores."""
    lengths = ctx["lengths"]
    tables = ctx["block_tables"]
    B = x.shape[0]
    k_cache = attn.paged_cache_insert(cache["k"], k_new, tables, lengths)
    v_cache = attn.paged_cache_insert(cache["v"], v_new, tables, lengths)
    impl = ctx.get("paged_attn", "xla")
    window = cfg.sliding_window if local else 0
    if impl in ("pallas", "pallas_interpret") and not window:
        from repro.kernels import ops as kops

        out = kops.paged_decode_attention(
            q[:, 0], k_cache.astype(x.dtype), v_cache.astype(x.dtype),
            tables, lengths + 1,
            softcap=cfg.attn_logit_softcap,
            interpret=(impl == "pallas_interpret"))[:, None]
    else:
        k_seq = attn.paged_gather(k_cache, tables)
        v_seq = attn.paged_gather(v_cache, tables)
        T = k_seq.shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        kv_valid = kv_pos < (lengths + 1)[:, None]
        out = attn.gqa_scores(
            q, k_seq.astype(x.dtype), v_seq.astype(x.dtype),
            q_positions=ctx["positions"], kv_positions=kv_pos,
            causal=True, window=window,
            softcap=cfg.attn_logit_softcap, kv_valid=kv_valid,
        )
    y = attn.output_proj(p["attn"], out, x.dtype)
    if post_norm:
        y = apply_norm(p["ln_attn_post"], y, cfg.norm, cfg.norm_eps)
    return h + y, {"k": k_cache, "v": v_cache}


def _apply_ffn_sub(p, h, ctx, cfg, *, use_moe: bool, post_norm: bool):
    x = apply_norm(p["ln_mlp"], h, cfg.norm, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if use_moe:
        y, aux = moe_lib.moe_apply(
            p["moe"], x, cfg, mesh=ctx["mesh"], impl=ctx["moe_impl"]
        )
    else:
        y = mlp_apply(p["mlp"], x, cfg.act_fn)
    if post_norm:
        y = apply_norm(p["ln_mlp_post"], y, cfg.norm, cfg.norm_eps)
    return h + y, aux


def _attn_block(p, carry, cache, ctx, cfg, *, local: bool, use_moe: bool,
                post_norm: bool):
    h, aux_acc = carry
    h = ctx["constrain"](h)
    h, new_cache = _apply_attn_sub(p, h, cache, ctx, cfg, local=local,
                                   post_norm=post_norm)
    h, aux = _apply_ffn_sub(p, h, ctx, cfg, use_moe=use_moe, post_norm=post_norm)
    return (h, aux_acc + aux), new_cache


def _mla_block(p, carry, cache, ctx, cfg, *, use_moe: bool):
    h, aux_acc = carry
    h = ctx["constrain"](h)
    x = apply_norm(p["ln_attn"], h, cfg.norm, cfg.norm_eps)
    B = x.shape[0]
    if ctx["mode"] == "train":
        y, _ = mla_lib.mla_apply(p["attn"], x, positions=ctx["positions"], cfg=cfg)
        new_cache = cache
    elif ctx["mode"] == "prefill":
        S = x.shape[1]
        y, (ckv, kr) = mla_lib.mla_apply(p["attn"], x, positions=ctx["positions"], cfg=cfg)
        new_cache = {
            "ckv": cache["ckv"].at[:, :S].set(ckv.astype(cache["ckv"].dtype)),
            "kr": cache["kr"].at[:, :S].set(kr.astype(cache["kr"].dtype)),
        }
    else:
        lengths = ctx["lengths"]
        ckv_new, kr_new = mla_lib.mla_project_kv(
            p["attn"], x, ctx["positions"], cfg)
        mode = ctx.get("cache_update", "scatter")
        ckv_c = attn.cache_insert(cache["ckv"], ckv_new, lengths, mode=mode,
                                  mesh=ctx["mesh"], rules=ctx.get("rules"))
        kr_c = attn.cache_insert(cache["kr"], kr_new, lengths, mode=mode,
                                 mesh=ctx["mesh"], rules=ctx.get("rules"))
        T = ckv_c.shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        kv_valid = kv_pos < (lengths + 1)[:, None]
        y = mla_lib.mla_attend(
            p["attn"], x, positions=ctx["positions"], cfg=cfg,
            ckv_all=ckv_c.astype(x.dtype), kr_all=kr_c.astype(x.dtype),
            kv_positions=kv_pos, kv_valid=kv_valid,
        )
        new_cache = {"ckv": ckv_c, "kr": kr_c}
    h = h + y
    h, aux = _apply_ffn_sub(p, h, ctx, cfg, use_moe=use_moe, post_norm=False)
    return (h, aux_acc + aux), new_cache


def _mamba_block(p, carry, cache, ctx, cfg):
    h, aux = carry
    h = ctx["constrain"](h)
    x = apply_norm(p["ln"], h, cfg.norm, cfg.norm_eps)
    state = cache if ctx["mode"] == "decode" else None
    y, new_state = m2.mamba2_apply(p["mamba"], x, cfg, state=state)
    new_cache = new_state if ctx["mode"] != "train" else cache
    return (h + y, aux), new_cache


def _mlstm_block(p, carry, cache, ctx, cfg):
    h, aux = carry
    h = ctx["constrain"](h)
    state = tuple(cache) if (ctx["mode"] == "decode" and cache is not None) else None
    y, new_state = xl.mlstm_apply(p, h, cfg, state=state)
    new_cache = list(new_state) if ctx["mode"] != "train" else cache
    return (h + y, aux), new_cache


def _slstm_block(p, carry, cache, ctx, cfg):
    h, aux = carry
    h = ctx["constrain"](h)
    state = tuple(cache) if (ctx["mode"] == "decode" and cache is not None) else None
    y, new_state = xl.slstm_apply(p, h, cfg, state=state)
    new_cache = list(new_state) if ctx["mode"] != "train" else cache
    return (h + y, aux), new_cache


# ---------------------------------------------------------------------------
# stages
# ---------------------------------------------------------------------------

@dataclass
class StageDef:
    name: str
    n: int                                   # scanned length
    block_specs: Any                         # unstacked per-block spec tree
    block_fn: Callable                       # (p, carry, cache_l, ctx) -> ((h,aux), cache_l')
    cache_specs: Callable | None             # (cfg, B, T, dtype) -> per-layer WSpec tree
    shared_specs: Any = None                 # non-scanned weights (zamba shared attn)


def _kv_cache_specs(cfg, B, T, dtype):
    K, D = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": WSpec((B, T, K, D), ("cache_batch", "cache_seq", "cache_heads", None),
                   init="zeros", dtype=dtype),
        "v": WSpec((B, T, K, D), ("cache_batch", "cache_seq", "cache_heads", None),
                   init="zeros", dtype=dtype),
    }


def _mla_cache_specs(cfg, B, T, dtype):
    return {
        "ckv": WSpec((B, T, cfg.kv_lora_rank),
                     ("cache_batch", "cache_seq", None), init="zeros", dtype=dtype),
        "kr": WSpec((B, T, cfg.qk_rope_dim),
                    ("cache_batch", "cache_seq", None), init="zeros", dtype=dtype),
    }


def _mamba_cache_specs(cfg, B, T, dtype):
    d_in, H, N = m2.mamba2_dims(cfg)
    W = cfg.mamba_conv_width
    return {
        "ssm": WSpec((B, H, N, cfg.mamba_head_dim),
                     ("cache_batch", "ssm_heads", None, None), init="zeros",
                     dtype=jnp.float32),
        "conv_x": WSpec((B, W - 1, d_in), ("cache_batch", None, "ssm_inner"),
                        init="zeros", dtype=dtype),
        "conv_B": WSpec((B, W - 1, N), ("cache_batch", None, None), init="zeros",
                        dtype=dtype),
        "conv_C": WSpec((B, W - 1, N), ("cache_batch", None, None), init="zeros",
                        dtype=dtype),
    }


def _mlstm_cache_specs(cfg, B, T, dtype):
    d_in, H, hd = xl.mlstm_dims(cfg)
    return [
        WSpec((B, H, hd, hd), ("cache_batch", "ssm_heads", None, None),
              init="zeros", dtype=jnp.float32),
        WSpec((B, H, hd), ("cache_batch", "ssm_heads", None), init="zeros",
              dtype=jnp.float32),
        WSpec((B, H), ("cache_batch", "ssm_heads"), init="zeros", dtype=jnp.float32),
    ]


def _slstm_cache_specs(cfg, B, T, dtype):
    d = cfg.d_model
    return [
        WSpec((B, d), ("cache_batch", None), init="zeros", dtype=jnp.float32)
        for _ in range(4)
    ]


def make_stages(cfg) -> list[StageDef]:
    fam = cfg.family
    stages: list[StageDef] = []

    if fam in ("dense", "vlm"):
        if cfg.attn_pattern:  # gemma2: scan over (local, global) pairs
            pat = cfg.attn_pattern
            n_pairs = cfg.n_layers // len(pat)

            pair_specs = {
                f"sub{i}": _attn_block_specs(cfg, False, cfg.post_norm)
                for i in range(len(pat))
            }

            def pair_fn(p, carry, cache, ctx, pat=pat):
                caches = []
                for i, kind in enumerate(pat):
                    carry, c = _attn_block(
                        p[f"sub{i}"], carry,
                        None if cache is None else cache[i], ctx, cfg,
                        local=(kind == "local"), use_moe=False,
                        post_norm=cfg.post_norm,
                    )
                    caches.append(c)
                return carry, caches

            def pair_cache(cfg_, B, T, dtype, k=len(pat)):
                return [_kv_cache_specs(cfg_, B, T, dtype) for _ in range(k)]

            stages.append(StageDef("pairs", n_pairs, pair_specs, pair_fn, pair_cache))
        else:
            stages.append(StageDef(
                "blocks", cfg.n_layers, _attn_block_specs(cfg, False, cfg.post_norm),
                partial(_attn_block, cfg=cfg, local=False, use_moe=False,
                        post_norm=cfg.post_norm),
                _kv_cache_specs,
            ))

    elif fam == "moe":
        if cfg.use_mla:
            if cfg.first_dense_layers:
                dense_cfg_specs = {
                    "ln_attn": norm_specs(cfg.d_model, cfg.norm),
                    "attn": mla_lib.mla_specs(cfg),
                    "ln_mlp": norm_specs(cfg.d_model, cfg.norm),
                    "mlp": mlp_specs(cfg.d_model, cfg.dense_d_ff or cfg.d_ff),
                }
                stages.append(StageDef(
                    "dense", cfg.first_dense_layers, dense_cfg_specs,
                    partial(_mla_block, cfg=cfg, use_moe=False), _mla_cache_specs,
                ))
            stages.append(StageDef(
                "moe", cfg.n_layers - cfg.first_dense_layers,
                _mla_block_specs(cfg, True),
                partial(_mla_block, cfg=cfg, use_moe=True), _mla_cache_specs,
            ))
        else:
            stages.append(StageDef(
                "moe", cfg.n_layers, _attn_block_specs(cfg, True, cfg.post_norm),
                partial(_attn_block, cfg=cfg, local=False, use_moe=True,
                        post_norm=cfg.post_norm),
                _kv_cache_specs,
            ))

    elif fam == "hybrid":  # zamba2: superblocks of mamba + shared attention
        k = cfg.n_mamba_per_super
        n_super = cfg.n_layers // k
        tail = cfg.n_layers - n_super * k
        super_specs = {"mamba": stack_specs(_mamba_block_specs(cfg), k)}
        shared = _shared_attn_specs(cfg)

        def super_fn(p, carry, cache, ctx, k=k):
            mcache = None if cache is None else cache["mamba"]

            def inner(lp, c, x_l):
                cc, cl = _mamba_block(lp, c, x_l if mcache is not None else None,
                                      ctx, cfg)
                return cc, (cl if mcache is not None else jnp.zeros((0,)))

            carry, mc = scan_stack(inner, p["mamba"], carry, xs=mcache,
                                   remat=ctx["remat"],
                                   unroll=ctx.get("unroll", False))
            # shared attention block (weights shared across superblocks)
            acache = None if cache is None else cache["attn"]
            carry, ac = _attn_block(ctx["shared_attn"], carry, acache, ctx, cfg,
                                    local=False, use_moe=False, post_norm=False)
            new_cache = None if cache is None else {"mamba": mc, "attn": ac}
            return carry, (new_cache if cache is not None else jnp.zeros((0,)))

        def super_cache(cfg_, B, T, dtype, k=k):
            return {
                "mamba": jax.tree.map(
                    lambda ws: dataclasses.replace(
                        ws, shape=(k, *ws.shape), axes=("layers", *ws.axes)),
                    _mamba_cache_specs(cfg_, B, T, dtype),
                    is_leaf=lambda x: isinstance(x, WSpec)),
                "attn": _kv_cache_specs(cfg_, B, T, dtype),
            }

        stages.append(StageDef("super", n_super, super_specs, super_fn,
                               super_cache, shared_specs=shared))
        if tail:
            stages.append(StageDef(
                "tail", tail, _mamba_block_specs(cfg), partial(_mamba_block, cfg=cfg),
                _mamba_cache_specs,
            ))

    elif fam == "ssm":  # xLSTM m:1 groups
        m = cfg.mlstm_to_slstm
        group = m + 1
        n_groups = cfg.n_layers // group
        group_specs = {
            "mlstm": stack_specs(xl.mlstm_specs(cfg), m),
            "slstm": xl.slstm_specs(cfg),
        }

        def group_fn(p, carry, cache, ctx, m=m):
            mcache = None if cache is None else cache["mlstm"]

            def inner(lp, c, x_l):
                cc, cl = _mlstm_block(lp, c, x_l if mcache is not None else None,
                                      ctx, cfg)
                return cc, (cl if mcache is not None else jnp.zeros((0,)))

            carry, mc = scan_stack(inner, p["mlstm"], carry, xs=mcache,
                                   remat=ctx["remat"],
                                   unroll=ctx.get("unroll", False))
            scache = None if cache is None else cache["slstm"]
            carry, sc = _slstm_block(p["slstm"], carry, scache, ctx, cfg)
            new_cache = None if cache is None else {"mlstm": mc, "slstm": sc}
            return carry, (new_cache if cache is not None else jnp.zeros((0,)))

        def group_cache(cfg_, B, T, dtype, m=m):
            return {
                "mlstm": [
                    jax.tree.map(
                        lambda ws: dataclasses.replace(
                            ws, shape=(m, *ws.shape), axes=("layers", *ws.axes)),
                        s, is_leaf=lambda x: isinstance(x, WSpec))
                    for s in _mlstm_cache_specs(cfg_, B, T, dtype)
                ],
                "slstm": _slstm_cache_specs(cfg_, B, T, dtype),
            }

        stages.append(StageDef("xgroup", n_groups, group_specs, group_fn,
                               group_cache))

    else:
        raise ValueError(f"make_stages: unsupported family {fam}")

    return stages
