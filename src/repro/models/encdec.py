"""Encoder-decoder model (whisper-tiny family).

The conv audio frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (B, encoder_seq, d_model).  The
encoder is a bidirectional transformer; the decoder adds cross-attention
over the encoder output.  Positions are sinusoidal (parameter-free; the
real model's learned decoder table is documented as a stand-in choice in
DESIGN.md).

S2M3 view: the encoder is a modality-wise *encoder module*; the decoder
is the *task head module*.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.common.sharding import merge_rules
from repro.layers import attention as attn_lib
from repro.layers.embedding import embed_apply, embed_specs, head_apply
from repro.layers.initializers import WSpec, stack_specs
from repro.layers.mlp import mlp_apply, mlp_specs
from repro.layers.norms import apply_norm, norm_specs
from repro.layers.stack import scan_stack

F32 = jnp.float32


def _is_ws(x):
    return isinstance(x, WSpec)


def sinusoid(positions, d_model):
    """positions: (B, S) -> (B, S, d) float32 sinusoidal embedding."""
    half = d_model // 2
    freq = jnp.exp(-jnp.arange(half, dtype=F32) * (math.log(10000.0) / max(half - 1, 1)))
    ang = positions[..., None].astype(F32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_block_specs(cfg):
    d = cfg.d_model
    return {
        "ln_attn": norm_specs(d, cfg.norm),
        "attn": attn_lib.attention_specs(d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim),
        "ln_mlp": norm_specs(d, cfg.norm),
        "mlp": mlp_specs(d, cfg.d_ff),
    }


def _dec_block_specs(cfg):
    d = cfg.d_model
    return {
        "ln_self": norm_specs(d, cfg.norm),
        "self_attn": attn_lib.attention_specs(d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim),
        "ln_cross": norm_specs(d, cfg.norm),
        "cross_attn": attn_lib.attention_specs(d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim),
        "ln_mlp": norm_specs(d, cfg.norm),
        "mlp": mlp_specs(d, cfg.d_ff),
    }


def _enc_block(p, h, ctx, cfg):
    x = apply_norm(p["ln_attn"], h, cfg.norm, cfg.norm_eps)
    y, _ = attn_lib.attention_apply(
        p["attn"], x, positions=ctx["positions"], cfg=cfg, causal=False,
        impl=ctx.get("attn_impl", "xla"),
    )
    h = h + y
    x = apply_norm(p["ln_mlp"], h, cfg.norm, cfg.norm_eps)
    return h + mlp_apply(p["mlp"], x, cfg.act_fn)


def _dec_block(p, h, cache, ctx, cfg, enc_out, enc_positions):
    """cache: {self: {k,v}, cross: {k,v}} or None (train)."""
    h = ctx.get("constrain", lambda x: x)(h)
    mode = ctx["mode"]
    positions = ctx["positions"]
    B = h.shape[0]

    # --- self attention ---
    x = apply_norm(p["ln_self"], h, cfg.norm, cfg.norm_eps)
    if mode == "train":
        y, _ = attn_lib.attention_apply(p["self_attn"], x, positions=positions, cfg=cfg)
        new_self = None
    elif mode == "prefill":
        S = x.shape[1]
        y, (k, v) = attn_lib.attention_apply(p["self_attn"], x, positions=positions, cfg=cfg)
        new_self = {
            "k": cache["self"]["k"].at[:, :S].set(k.astype(cache["self"]["k"].dtype)),
            "v": cache["self"]["v"].at[:, :S].set(v.astype(cache["self"]["v"].dtype)),
        }
    else:
        lengths = ctx["lengths"]
        q, k_new, v_new = attn_lib.project_qkv(p["self_attn"], x, positions, cfg)
        mode = ctx.get("cache_update", "scatter")
        k_c = attn_lib.cache_insert(cache["self"]["k"], k_new, lengths,
                                    mode=mode, mesh=ctx.get("mesh"),
                                    rules=ctx.get("rules"))
        v_c = attn_lib.cache_insert(cache["self"]["v"], v_new, lengths,
                                    mode=mode, mesh=ctx.get("mesh"),
                                    rules=ctx.get("rules"))
        T = k_c.shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        kv_valid = kv_pos < (lengths + 1)[:, None]
        out = attn_lib.gqa_scores(
            q, k_c.astype(x.dtype), v_c.astype(x.dtype),
            q_positions=positions, kv_positions=kv_pos, causal=True,
            kv_valid=kv_valid,
        )
        y = attn_lib.output_proj(p["self_attn"], out, x.dtype)
        new_self = {"k": k_c, "v": v_c}
    h = h + y

    # --- cross attention ---
    x = apply_norm(p["ln_cross"], h, cfg.norm, cfg.norm_eps)
    if mode == "train":
        ck, cv = attn_lib.cross_kv_project(p["cross_attn"], enc_out, cfg)
        new_cross = None
    elif mode == "prefill":
        ck, cv = attn_lib.cross_kv_project(p["cross_attn"], enc_out, cfg)
        new_cross = {"k": ck.astype(cache["cross"]["k"].dtype),
                     "v": cv.astype(cache["cross"]["v"].dtype)}
    else:
        ck = cache["cross"]["k"].astype(x.dtype)
        cv = cache["cross"]["v"].astype(x.dtype)
        new_cross = {"k": cache["cross"]["k"], "v": cache["cross"]["v"]}
    y, _ = attn_lib.attention_apply(
        p["cross_attn"], x, positions=positions, cfg=cfg,
        cross_kv=(ck, cv), cross_positions=enc_positions,
    )
    h = h + y

    x = apply_norm(p["ln_mlp"], h, cfg.norm, cfg.norm_eps)
    h = h + mlp_apply(p["mlp"], x, cfg.act_fn)
    new_cache = None if mode == "train" else {"self": new_self, "cross": new_cross}
    return h, new_cache


def _encode(cfg, params, frames, compute_dtype, opts):
    B, S = frames.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    h = frames.astype(compute_dtype)
    h = jnp.einsum("bsd,de->bse", h, params["audio_proj"]["w"].astype(compute_dtype))
    h = h + sinusoid(positions, cfg.d_model).astype(compute_dtype)
    ctx = {"positions": positions, "attn_impl": opts.get("attn_impl", "xla")}

    def fn(lp, c, x_l):
        return _enc_block(lp, c, ctx, cfg), jnp.zeros((0,))

    h, _ = scan_stack(fn, params["encoder"], h, remat=opts.get("remat", "full"),
                      unroll=opts.get("scan_unroll", False))
    h = apply_norm(params["enc_norm"], h, cfg.norm, cfg.norm_eps)
    return h, positions


def build_encdec(cfg, mesh=None, rules=None, **opts):
    from repro.models.api import ModelBundle, _constrainer, cross_entropy

    rules = merge_rules(rules if isinstance(rules, dict) else None)
    compute_dtype = opts.get("compute_dtype", jnp.bfloat16)
    n_dec = cfg.n_layers

    specs: dict[str, Any] = {
        "audio_proj": {"w": WSpec((cfg.d_model, cfg.d_model), (None, "embed"))},
        "encoder": stack_specs(_enc_block_specs(cfg), cfg.n_encoder_layers),
        "enc_norm": norm_specs(cfg.d_model, cfg.norm),
        "embed": embed_specs(cfg.vocab_size, cfg.d_model),
        "decoder": stack_specs(_dec_block_specs(cfg), n_dec),
        "final_norm": norm_specs(cfg.d_model, cfg.norm),
    }
    # whisper ties decoder embedding and output head
    tied = True

    def _dec_embed(params, tokens, positions):
        h = embed_apply(params["embed"], tokens, dtype=compute_dtype)
        return h + sinusoid(positions, cfg.d_model).astype(compute_dtype)

    def _head(params, h):
        return head_apply(None, h, tied_table=params["embed"]["table"])

    def _run_decoder(params, h, ctx, cache, enc_out, enc_positions):
        def fn(lp, c, x_l, has_cache=cache is not None):
            hh, cc = _dec_block(lp, c[0], x_l if has_cache else None, ctx, cfg,
                                enc_out, enc_positions)
            return (hh, c[1]), (cc if has_cache else jnp.zeros((0,)))

        carry, ys = scan_stack(fn, params["decoder"], (h, jnp.zeros((), F32)),
                               xs=cache, remat=ctx["remat"],
                               unroll=ctx.get("unroll", False))
        return carry[0], (ys if cache is not None else None)

    def loss_fn(params, batch):
        enc_out, enc_pos = _encode(cfg, params, batch["audio_frames"],
                                   compute_dtype, opts)
        B, S = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        ctx = {"mode": "train", "positions": positions, "lengths": None,
               "remat": opts.get("remat", "full"),
               "unroll": opts.get("scan_unroll", False),
               "constrain": _constrainer(mesh, rules)}
        h = _dec_embed(params, batch["tokens"], positions)
        h, _ = _run_decoder(params, h, ctx, None, enc_out, enc_pos)
        h = apply_norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)
        logits = _head(params, h)
        loss = cross_entropy(logits, batch["targets"], batch["mask"])
        return loss, {"loss": loss, "ce": loss}

    def prefill(params, batch, cache):
        enc_out, enc_pos = _encode(cfg, params, batch["audio_frames"],
                                   compute_dtype, opts)
        B, S = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        lengths = batch.get("lengths")
        if lengths is None:
            lengths = jnp.full((B,), S, jnp.int32)
        ctx = {"mode": "prefill", "positions": positions, "lengths": lengths,
               "remat": "none", "unroll": opts.get("scan_unroll", False),
               "constrain": _constrainer(mesh, rules)}
        h = _dec_embed(params, batch["tokens"], positions)
        h, new_cache = _run_decoder(params, h, ctx, cache, enc_out, enc_pos)
        h = apply_norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)
        last = jnp.clip(lengths - 1, 0, S - 1)
        logits = _head(params, h[jnp.arange(B), last][:, None])[:, 0]
        return logits, new_cache

    def decode_step(params, tokens, cache, lengths):
        B = tokens.shape[0]
        positions = lengths[:, None].astype(jnp.int32)
        ctx = {"mode": "decode", "positions": positions, "lengths": lengths,
               "remat": "none", "unroll": opts.get("scan_unroll", False),
               "constrain": _constrainer(mesh, rules)}
        h = _dec_embed(params, tokens, positions)
        enc_pos = jnp.broadcast_to(
            jnp.arange(cfg.encoder_seq, dtype=jnp.int32), (B, cfg.encoder_seq))
        h, new_cache = _run_decoder(params, h, ctx, cache, None, enc_pos)
        h = apply_norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)
        logits = _head(params, h)[:, 0]
        return logits, new_cache

    def cache_specs(B, T, dtype=jnp.bfloat16):
        K, D = cfg.n_kv_heads, cfg.head_dim
        kv = lambda t: {
            "k": WSpec((B, t, K, D), ("cache_batch", "cache_seq", "cache_heads", None),
                       init="zeros", dtype=dtype),
            "v": WSpec((B, t, K, D), ("cache_batch", "cache_seq", "cache_heads", None),
                       init="zeros", dtype=dtype),
        }
        per_layer = {"self": kv(T), "cross": kv(cfg.encoder_seq)}
        return jax.tree.map(
            lambda ws: dataclasses.replace(ws, shape=(n_dec, *ws.shape),
                                           axes=("layers", *ws.axes)),
            per_layer, is_leaf=_is_ws)

    def batch_specs(shape):
        B, S = shape.global_batch, shape.seq_len
        frames = WSpec((B, cfg.encoder_seq, cfg.d_model), ("batch", None, None),
                       dtype=compute_dtype)
        if shape.kind == "train":
            return {
                "tokens": WSpec((B, S), ("batch", "seq"), dtype=jnp.int32),
                "targets": WSpec((B, S), ("batch", "seq"), dtype=jnp.int32),
                "mask": WSpec((B, S), ("batch", "seq"), dtype=F32),
                "audio_frames": frames,
            }
        if shape.kind == "prefill":
            return {
                "tokens": WSpec((B, S), ("batch", "seq"), dtype=jnp.int32),
                "lengths": WSpec((B,), ("batch",), dtype=jnp.int32),
                "audio_frames": frames,
            }
        return {
            "tokens": WSpec((B, 1), ("batch", None), dtype=jnp.int32),
            "lengths": WSpec((B,), ("batch",), dtype=jnp.int32),
        }

    return ModelBundle(
        cfg=cfg, specs=specs, loss_fn=loss_fn, prefill=prefill,
        decode_step=decode_step, cache_specs=cache_specs,
        batch_specs=batch_specs, mesh=mesh, rules=rules,
    )
