"""Model zoo: decoder LMs (dense/MoE/MLA/hybrid/SSM), enc-dec, VLM."""
