"""Public model API: build_model(cfg) -> ModelBundle.

A ModelBundle packages weight specs + pure step functions for one
architecture.  All functions are jit-compatible; the dry-run lowers them
with ShapeDtypeStruct inputs derived from the same WSpec trees.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.common.config import ArchConfig, ShapeConfig
from repro.common.sharding import merge_rules, spec_for
from repro.layers import attention as attn_lib
from repro.layers import mla as mla_lib
from repro.layers.embedding import embed_apply, embed_specs, head_apply, head_specs
from repro.layers.initializers import (
    WSpec, abstract_tree, init_tree, spec_param_count, stack_specs,
)
from repro.layers.mlp import mlp_specs
from repro.layers.norms import apply_norm, norm_specs
from repro.layers.stack import scan_stack
from repro.models import encdec as encdec_lib
from repro.models.lm import StageDef, make_stages

F32 = jnp.float32


def _is_ws(x):
    return isinstance(x, WSpec)


# ---------------------------------------------------------------------------
# bundle
# ---------------------------------------------------------------------------

@dataclass
class ModelBundle:
    cfg: ArchConfig
    specs: Any                       # weights WSpec tree
    loss_fn: Callable                # (params, batch) -> (loss, metrics)
    prefill: Callable                # (params, batch, cache) -> (logits_last, cache)
    decode_step: Callable            # (params, tokens, cache, lengths) -> (logits, cache)
    cache_specs: Callable            # (B, T) -> WSpec tree
    batch_specs: Callable            # (ShapeConfig) -> WSpec tree
    mesh: Any = None
    rules: Any = None
    # paged-KV decode (serving substrate); None for cache families the
    # page layout doesn't cover (state-space / MLA / enc-dec caches)
    paged_decode_step: Callable | None = None   # (params, tokens, cache,
    #                                              block_tables, lengths)
    paged_cache_specs: Callable | None = None   # (n_pages, page_size, dtype)

    def init(self, key, param_dtype=jnp.float32):
        return init_tree(key, self.specs, param_dtype)

    def abstract_params(self, param_dtype=jnp.bfloat16):
        return abstract_tree(self.specs, param_dtype)

    def param_count(self) -> int:
        return spec_param_count(self.specs)

    def init_cache(self, B: int, T: int, dtype=jnp.bfloat16):
        return init_tree(jax.random.PRNGKey(0), self.cache_specs(B, T, dtype))

    @property
    def supports_paged_decode(self) -> bool:
        return self.paged_decode_step is not None

    def init_paged_cache(self, n_pages: int, page_size: int,
                         dtype=jnp.bfloat16):
        if self.paged_cache_specs is None:
            raise NotImplementedError(
                f"family {self.cfg.family!r} has no paged-KV cache layout "
                "(only pure-attention caches page)")
        return init_tree(jax.random.PRNGKey(0),
                         self.paged_cache_specs(n_pages, page_size, dtype))

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k of routed experts)."""
        cfg = self.cfg
        total = self.param_count()
        if not cfg.n_experts:
            return total
        from repro.layers.moe import padded_experts

        f = cfg.moe_d_ff or cfg.d_ff
        per_expert = 3 * cfg.d_model * f
        n_moe_layers = cfg.n_layers - cfg.first_dense_layers
        routed = padded_experts(cfg) * per_expert * n_moe_layers
        active = cfg.experts_top_k * per_expert * n_moe_layers
        return total - routed + active


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------

def _constrainer(mesh, rules):
    if mesh is None:
        return lambda h: h

    def constrain(h):
        spec = spec_for(h.shape, ("batch", "seq", "act_embed"), rules, mesh)
        return jax.lax.with_sharding_constraint(
            h, jax.sharding.NamedSharding(mesh, spec))

    return constrain


def _make_ctx(cfg, mesh, rules, mode, positions, lengths, opts):
    return {
        "mode": mode,
        "positions": positions,
        "lengths": lengths,
        "mesh": mesh,
        "remat": opts.get("remat", "full") if mode == "train" else "none",
        "moe_impl": opts.get("moe_impl", "ep" if mesh is not None else "dense"),
        "attn_impl": opts.get("attn_impl", "xla"),
        "unroll": opts.get("scan_unroll", False),
        "cache_update": opts.get("cache_update", "scatter"),
        "decode_attn": opts.get("decode_attn", "default"),
        "paged_attn": opts.get("paged_attn", "xla"),
        "attn_sp": opts.get("attn_sp", False),
        "softmax_dtype": opts.get("softmax_dtype", jnp.float32),
        "rules": rules,
        "constrain": _constrainer(mesh, rules),
    }


def _run_backbone(stages, params, h, ctx, caches):
    """Run all stages; returns (h, aux_loss, new_caches)."""
    carry = (h, jnp.zeros((), F32))
    new_caches = {}
    for st in stages:
        p_st = params["stages"][st.name]
        ctx_st = dict(ctx)
        if st.shared_specs is not None:
            ctx_st["shared_attn"] = p_st["shared"]
        cache_st = None if caches is None else caches[st.name]

        def fn(lp, c, x_l, st=st, ctx_st=ctx_st, has_cache=cache_st is not None):
            c2, cache_l = st.block_fn(lp, c, x_l if has_cache else None, ctx_st)
            y = cache_l if has_cache else jnp.zeros((0,))
            return (c2[0], c2[1]), y

        carry, ys = scan_stack(
            fn, p_st["blocks"], carry, xs=cache_st, remat=ctx["remat"],
            unroll=ctx.get("unroll", False),
        )
        if caches is not None:
            new_caches[st.name] = ys
    return carry[0], carry[1], new_caches


def _lm_specs(cfg, stages):
    sp: dict[str, Any] = {
        "embed": embed_specs(cfg.vocab_size, cfg.d_model),
        "stages": {},
        "final_norm": norm_specs(cfg.d_model, cfg.norm),
    }
    for st in stages:
        entry = {"blocks": stack_specs(st.block_specs, st.n)}
        if st.shared_specs is not None:
            entry["shared"] = st.shared_specs
        sp["stages"][st.name] = entry
    if not cfg.tie_embeddings:
        sp["head"] = head_specs(cfg.d_model, cfg.vocab_size)
    if cfg.has_vision_stub:
        sp["img_proj"] = {
            "w": WSpec((cfg.d_model, cfg.d_model), (None, "embed"))
        }
    if cfg.mtp_depth:
        sp["mtp"] = {
            "proj": WSpec((2 * cfg.d_model, cfg.d_model), (None, "embed")),
            "norm_h": norm_specs(cfg.d_model, cfg.norm),
            "norm_e": norm_specs(cfg.d_model, cfg.norm),
            "block": {
                "ln_attn": norm_specs(cfg.d_model, cfg.norm),
                "attn": mla_lib.mla_specs(cfg) if cfg.use_mla
                else attn_lib.attention_specs(
                    cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim),
                "ln_mlp": norm_specs(cfg.d_model, cfg.norm),
                "mlp": mlp_specs(cfg.d_model, cfg.dense_d_ff or cfg.d_ff),
            },
            "final_norm": norm_specs(cfg.d_model, cfg.norm),
        }
    return sp


def _embed_inputs(cfg, params, batch, compute_dtype):
    """Token (+modality-stub) embedding. Returns (h, n_prefix)."""
    scale = math.sqrt(cfg.d_model) if cfg.embed_scale_by_dim else 1.0
    h = embed_apply(params["embed"], batch["tokens"], scale=scale,
                    dtype=compute_dtype)
    n_prefix = 0
    if cfg.has_vision_stub:
        img = batch["image_embeds"].astype(compute_dtype)
        img = jnp.einsum("bnd,de->bne", img, params["img_proj"]["w"].astype(compute_dtype))
        h = jnp.concatenate([img, h], axis=1)
        n_prefix = img.shape[1]
    return h, n_prefix


def _logits(cfg, params, h):
    tied = params["embed"]["table"] if cfg.tie_embeddings else None
    return head_apply(params.get("head"), h, softcap=cfg.final_logit_softcap,
                      tied_table=tied)


def cross_entropy(logits, targets, mask, z_loss=0.0):
    logits = logits.astype(F32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = (lse - tgt) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = ce.sum() / denom
    if z_loss:
        loss = loss + z_loss * ((lse * mask) ** 2).sum() / denom
    return loss


def _mtp_loss(cfg, params, h, batch, ctx, compute_dtype):
    """Simplified DeepSeek MTP: one extra block predicting token t+2."""
    p = params["mtp"]
    tok_next = batch["tokens"][:, 1:]
    emb = embed_apply(params["embed"], tok_next, dtype=compute_dtype)
    hh = apply_norm(p["norm_h"], h[:, :-1], cfg.norm, cfg.norm_eps)
    ee = apply_norm(p["norm_e"], emb, cfg.norm, cfg.norm_eps)
    x = jnp.einsum("bsd,df->bsf", jnp.concatenate([hh, ee], -1),
                   p["proj"].astype(compute_dtype))
    positions = ctx["positions"][:, 1:]
    blk = p["block"]
    xn = apply_norm(blk["ln_attn"], x, cfg.norm, cfg.norm_eps)
    if cfg.use_mla:
        y, _ = mla_lib.mla_apply(blk["attn"], xn, positions=positions, cfg=cfg)
    else:
        y, _ = attn_lib.attention_apply(blk["attn"], xn, positions=positions, cfg=cfg)
    x = x + y
    from repro.layers.mlp import mlp_apply

    x = x + mlp_apply(blk["mlp"], apply_norm(blk["ln_mlp"], x, cfg.norm,
                                             cfg.norm_eps), cfg.act_fn)
    x = apply_norm(p["final_norm"], x, cfg.norm, cfg.norm_eps)
    logits = _logits(cfg, params, x)
    # target at t+2 == targets shifted one left
    tgt = batch["targets"][:, 1:]
    msk = batch["mask"][:, 1:] * (jnp.arange(tgt.shape[1]) < tgt.shape[1] - 1)
    return cross_entropy(logits, tgt, msk)


def build_model(cfg: ArchConfig, mesh=None, rules=None, **opts) -> ModelBundle:
    if cfg.is_encoder_decoder:
        return encdec_lib.build_encdec(cfg, mesh=mesh, rules=rules, **opts)

    rules = merge_rules(rules if isinstance(rules, dict) else None)
    stages = make_stages(cfg)
    specs = _lm_specs(cfg, stages)
    compute_dtype = opts.get("compute_dtype", jnp.bfloat16)

    # ---- loss (train) ----
    def loss_fn(params, batch):
        h, n_prefix = _embed_inputs(cfg, params, batch, compute_dtype)
        B, S = h.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        ctx = _make_ctx(cfg, mesh, rules, "train", positions, None, opts)
        h = ctx["constrain"](h)
        h, aux, _ = _run_backbone(stages, params, h, ctx, None)
        h = apply_norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)
        if n_prefix:
            h = h[:, n_prefix:]
        logits = _logits(cfg, params, h)
        loss = cross_entropy(logits, batch["targets"], batch["mask"],
                             opts.get("z_loss", 0.0))
        metrics = {"ce": loss, "aux": aux}
        if cfg.router_aux_loss and cfg.n_experts:
            loss = loss + cfg.router_aux_loss * aux
        if cfg.mtp_depth:
            ctx_m = _make_ctx(cfg, mesh, rules, "train", positions, None, opts)
            mtp = _mtp_loss(cfg, params, h if not n_prefix else h,
                            batch, ctx_m, compute_dtype)
            metrics["mtp"] = mtp
            loss = loss + 0.3 * mtp
        metrics["loss"] = loss
        return loss, metrics

    # ---- prefill ----
    def prefill(params, batch, cache):
        h, n_prefix = _embed_inputs(cfg, params, batch, compute_dtype)
        B, S = h.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        lengths = batch.get("lengths")
        if lengths is None:
            lengths = jnp.full((B,), S, jnp.int32)
        ctx = _make_ctx(cfg, mesh, rules, "prefill", positions, lengths, opts)
        h = ctx["constrain"](h)
        h, _, new_caches = _run_backbone(stages, params, h, ctx, cache)
        h = apply_norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)
        last = jnp.clip(lengths - 1, 0, S - 1)
        h_last = h[jnp.arange(B), last][:, None, :]
        logits = _logits(cfg, params, h_last)[:, 0]
        return logits, new_caches

    # ---- decode ----
    def decode_step(params, tokens, cache, lengths):
        h = embed_apply(
            params["embed"], tokens,
            scale=math.sqrt(cfg.d_model) if cfg.embed_scale_by_dim else 1.0,
            dtype=compute_dtype)
        B = h.shape[0]
        positions = lengths[:, None].astype(jnp.int32)
        ctx = _make_ctx(cfg, mesh, rules, "decode", positions, lengths, opts)
        h, _, new_caches = _run_backbone(stages, params, h, ctx, cache)
        h = apply_norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)
        logits = _logits(cfg, params, h)[:, 0]
        return logits, new_caches

    # ---- paged decode (serving substrate) ----
    # Every (dense/vlm) stage cache is a {"k","v"} pytree whose leaves
    # are (B, T, K, D): re-parameterizing (B, T) as (n_pages, page_size)
    # yields the global page pool the batched paged decode kernel and
    # block-table scatter consume.  State-space / MLA / enc-dec caches
    # don't fit the page layout; those bundles keep the fields None.
    paged_supported = cfg.family in ("dense", "vlm")

    def paged_decode_step(params, tokens, cache, block_tables, lengths):
        h = embed_apply(
            params["embed"], tokens,
            scale=math.sqrt(cfg.d_model) if cfg.embed_scale_by_dim else 1.0,
            dtype=compute_dtype)
        positions = lengths[:, None].astype(jnp.int32)
        ctx = _make_ctx(cfg, mesh, rules, "decode", positions, lengths, opts)
        ctx["cache_layout"] = "paged"
        ctx["block_tables"] = block_tables
        h, _, new_caches = _run_backbone(stages, params, h, ctx, cache)
        h = apply_norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)
        logits = _logits(cfg, params, h)[:, 0]
        return logits, new_caches

    def paged_cache_specs(n_pages, page_size, dtype=jnp.bfloat16):
        return cache_specs(n_pages, page_size, dtype)

    # ---- cache / batch specs ----
    def cache_specs(B, T, dtype=jnp.bfloat16):
        out = {}
        for st in stages:
            if st.cache_specs is None:
                continue
            per_layer = st.cache_specs(cfg, B, T, dtype)
            out[st.name] = jax.tree.map(
                lambda ws: dataclasses.replace(
                    ws, shape=(st.n, *ws.shape), axes=("layers", *ws.axes)),
                per_layer, is_leaf=_is_ws)
        return out

    def batch_specs(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        text = S
        extra = {}
        if cfg.has_vision_stub:
            text = S - cfg.n_image_tokens
            extra["image_embeds"] = WSpec(
                (B, cfg.n_image_tokens, cfg.d_model), ("batch", None, None),
                dtype=compute_dtype)
        if shape.kind == "train":
            return {
                "tokens": WSpec((B, text), ("batch", "seq"), dtype=jnp.int32),
                "targets": WSpec((B, text), ("batch", "seq"), dtype=jnp.int32),
                "mask": WSpec((B, text), ("batch", "seq"), dtype=F32),
                **extra,
            }
        if shape.kind == "prefill":
            return {
                "tokens": WSpec((B, text), ("batch", "seq"), dtype=jnp.int32),
                "lengths": WSpec((B,), ("batch",), dtype=jnp.int32),
                **extra,
            }
        # decode
        return {
            "tokens": WSpec((B, 1), ("batch", None), dtype=jnp.int32),
            "lengths": WSpec((B,), ("batch",), dtype=jnp.int32),
        }

    return ModelBundle(
        cfg=cfg, specs=specs, loss_fn=loss_fn, prefill=prefill,
        decode_step=decode_step, cache_specs=cache_specs,
        batch_specs=batch_specs, mesh=mesh, rules=rules,
        paged_decode_step=paged_decode_step if paged_supported else None,
        paged_cache_specs=paged_cache_specs if paged_supported else None,
    )
