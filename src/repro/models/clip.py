"""CLIP-style dual encoder (the paper's own testbed model family).

Vision encoder (over stub patch embeddings) + text encoder + cosine-
similarity head — exactly the three S2M3 functional modules of the
paper's image-text-retrieval task (Fig. 1a).  Used by the sharing-
equivalence tests and the distributed serving engine demo: the split
model's outputs must be bit-identical to the monolithic one (paper Q3).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.layers import attention as attn_lib
from repro.layers.embedding import embed_apply, embed_specs
from repro.layers.initializers import WSpec, init_tree, stack_specs
from repro.layers.mlp import mlp_apply, mlp_specs
from repro.layers.norms import apply_norm, norm_specs
from repro.layers.stack import scan_stack


@dataclass(frozen=True)
class ClipConfig:
    name: str
    vision_layers: int
    vision_width: int
    vision_heads: int
    text_layers: int
    text_width: int
    text_heads: int
    vocab_size: int
    embed_dim: int           # shared contrastive space
    n_image_tokens: int = 16
    norm_eps: float = 1e-5


@dataclass(frozen=True)
class _TowerCfg:
    """Adapter so we can reuse repro.layers.attention."""
    rope_theta: float = 10000.0
    use_rope: bool = False
    sliding_window: int = 0
    attn_logit_softcap: float = 0.0


def _tower_specs(width: int, heads: int, layers: int):
    block = {
        "ln1": norm_specs(width, "layernorm"),
        "attn": attn_lib.attention_specs(width, heads, heads, width // heads),
        "ln2": norm_specs(width, "layernorm"),
        "mlp": mlp_specs(width, 4 * width),
    }
    return stack_specs(block, layers)


def _tower_apply(params, h, *, causal: bool, eps: float):
    B, S = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    tc = _TowerCfg()

    def fn(lp, c, x_l):
        x = apply_norm(lp["ln1"], c, "layernorm", eps)
        y, _ = attn_lib.attention_apply(lp["attn"], x, positions=positions,
                                        cfg=tc, causal=causal)
        c = c + y
        x = apply_norm(lp["ln2"], c, "layernorm", eps)
        return c + mlp_apply(lp["mlp"], x, "gelu"), jnp.zeros((0,))

    h, _ = scan_stack(fn, params, h, remat="none")
    return h


def clip_specs(cfg: ClipConfig):
    return {
        "vision": {
            "patch_proj": WSpec((cfg.vision_width, cfg.vision_width),
                                (None, "embed")),
            "pos": WSpec((cfg.n_image_tokens, cfg.vision_width), (None, "embed"),
                         init="small"),
            "blocks": _tower_specs(cfg.vision_width, cfg.vision_heads,
                                   cfg.vision_layers),
            "ln_post": norm_specs(cfg.vision_width, "layernorm"),
            "proj": WSpec((cfg.vision_width, cfg.embed_dim), ("embed", None)),
        },
        "text": {
            "embed": embed_specs(cfg.vocab_size, cfg.text_width),
            "pos": WSpec((512, cfg.text_width), (None, "embed"), init="small"),
            "blocks": _tower_specs(cfg.text_width, cfg.text_heads,
                                   cfg.text_layers),
            "ln_final": norm_specs(cfg.text_width, "layernorm"),
            "proj": WSpec((cfg.text_width, cfg.embed_dim), ("embed", None)),
        },
        "logit_scale": WSpec((), (), init="zeros"),
    }


def encode_image(params, patches, cfg: ClipConfig, dtype=jnp.float32):
    """patches: (B, n_image_tokens, vision_width) stub embeddings."""
    h = patches.astype(dtype)
    h = jnp.einsum("bnd,de->bne", h, params["patch_proj"].astype(dtype))
    h = h + params["pos"].astype(dtype)[None]
    h = _tower_apply(params["blocks"], h, causal=False, eps=cfg.norm_eps)
    h = apply_norm(params["ln_post"], h.mean(axis=1, keepdims=True),
                   "layernorm", cfg.norm_eps)[:, 0]
    z = jnp.einsum("bd,de->be", h, params["proj"].astype(dtype))
    return z / jnp.linalg.norm(z, axis=-1, keepdims=True)


def encode_text(params, ids, cfg: ClipConfig, dtype=jnp.float32):
    """ids: (B, S) int32; EOT = last token."""
    h = embed_apply(params["embed"], ids, dtype=dtype)
    S = ids.shape[1]
    h = h + params["pos"].astype(dtype)[None, :S]
    h = _tower_apply(params["blocks"], h, causal=True, eps=cfg.norm_eps)
    h = apply_norm(params["ln_final"], h, "layernorm", cfg.norm_eps)
    z = jnp.einsum("bd,de->be", h[:, -1], params["proj"].astype(dtype))
    return z / jnp.linalg.norm(z, axis=-1, keepdims=True)


def retrieval_logits(img_z, txt_z, logit_scale):
    """Cosine-similarity task head (the paper's retrieval head)."""
    return jnp.exp(logit_scale) * img_z @ txt_z.T


def clip_forward(params, patches, ids, cfg: ClipConfig, dtype=jnp.float32):
    """Monolithic forward — the oracle the split execution must match."""
    zi = encode_image(params["vision"], patches, cfg, dtype)
    zt = encode_text(params["text"], ids, cfg, dtype)
    return retrieval_logits(zi, zt, params["logit_scale"])


def contrastive_loss(params, patches, ids, cfg: ClipConfig):
    logits = clip_forward(params, patches, ids, cfg)
    n = logits.shape[0]
    labels = jnp.arange(n)
    li = -jax.nn.log_softmax(logits, axis=1)[jnp.arange(n), labels].mean()
    lt = -jax.nn.log_softmax(logits, axis=0)[labels, jnp.arange(n)].mean()
    return 0.5 * (li + lt)


def init_clip(key, cfg: ClipConfig, dtype=jnp.float32):
    return init_tree(key, clip_specs(cfg), dtype)
