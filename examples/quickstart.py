"""Quickstart: build an assigned architecture, train a few steps on the
synthetic corpus, then generate with the continuous-batching server.

    python examples/quickstart.py [--arch tinyllama-1.1b]

(pytest.ini sets pythonpath=src; outside pytest, prefix PYTHONPATH=src.)

This file covers the single-model train/serve loop.  For the paper's
actual contribution — multi-task, multi-device split-and-share serving —
the stable entry point is the ``repro.s2m3.Deployment`` facade:

    from repro.s2m3 import Deployment, Request
    dep = (Deployment(cluster)
           .add_model(spec, builders)
           .plan(placement="greedy", routing="queue_aware")
           .materialize())
    dep.simulate(workload)   # predicted latency + memory ledger
    dep.submit(workload[0])  # real compute, same Request object

See examples/multi_task_serving.py (live engine) and
examples/edge_placement_sim.py (testbed simulator) for full tours, and
the "Public API" section of ROADMAP.md.
"""

import argparse

import jax
import jax.numpy as jnp

from repro.common.config import TrainConfig, get_config
from repro.core.routing import Request
from repro.models.api import build_model
from repro.serving.scheduler import lm_scheduler
from repro.training.data import DataConfig, TokenStream
from repro.training.optimizer import init_state
from repro.training.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)   # reduced config: CPU-friendly
    print(f"arch={cfg.name} family={cfg.family} (reduced smoke config)")
    bundle = build_model(cfg, compute_dtype=jnp.float32)
    print(f"params: {bundle.param_count():,}")

    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5,
                       total_steps=args.steps)
    state = init_state(bundle.init(jax.random.PRNGKey(0)), tcfg)
    step = jax.jit(make_train_step(bundle, tcfg))
    data = TokenStream(DataConfig(seq_len=64, global_batch=8,
                                  vocab_size=cfg.vocab_size))
    for i, batch in zip(range(args.steps), data):
        state, metrics = step(state, {k: jnp.asarray(v)
                                      for k, v in batch.items()})
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:3d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}")

    print("\nserving with continuous batching (paged KV decode):")
    sched = lm_scheduler(bundle, state["params"])
    reqs = [Request(rid=i, model="lm", source="dev0",
                    prompt=(1 + i, 2, 3), max_new_tokens=12)
            for i in range(6)]
    for r in sched.serve(reqs):
        print(f"  req {r.rid}: -> {list(r.output)}")
    st = sched.stats_dict()[cfg.name]
    print(f"  {st['decode_tokens']} tokens in {st['decode_steps']} batched "
          f"decode steps, peak pages {st['pages_peak']}")


if __name__ == "__main__":
    main()
