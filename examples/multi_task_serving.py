"""S2M3 end-to-end serving driver (the paper's scenario, real compute).

Everything goes through the ``s2m3.Deployment`` facade: admit THREE
multi-modal tasks that share encoders (retrieval / classification / VQA
with a tiny LM head), plan a greedy placement over 8 logical devices,
materialize on real jax devices, then drive the SAME ``Request`` objects
through the latency simulator and the live engine — predicted routes and
real routes line up, and the sharing ledger shows the dedup savings.
The serve() pass then demonstrates the observability layer: per-task
SLO-attainment summary, a Chrome-trace export of the request span trees
(``multi_task_trace.json``), and a ``dep.compare()`` drift report.

    PYTHONPATH=src python examples/multi_task_serving.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.s2m3_zoo import get_clip_config
from repro.core.cluster import ClusterSpec, DeviceSpec
from repro.core.module import ModelSpec, ModuleSpec
from repro.models import clip as C
from repro.s2m3 import Deployment, Request

GB = 1024**3


def main():
    devs = jax.devices()
    print(f"{len(devs)} devices available")

    # ---- module & model specs (Table II in miniature) ----
    ccfg = get_clip_config("mini-clip")
    params = C.init_clip(jax.random.PRNGKey(0), ccfg)

    vis = ModuleSpec("mini-vit", "encoder", "vision", 60_000,
                     flops_per_query=2e6)
    txt = ModuleSpec("mini-trf", "encoder", "text", 50_000,
                     flops_per_query=1e6)
    cos = ModuleSpec("cosine", "head", "task", 0)
    cls = ModuleSpec("mini-classifier", "head", "task", 1_000,
                     flops_per_query=1e4)
    lm = ModuleSpec("mini-lm", "head", "task", 80_000, flops_per_query=4e6)

    retrieval = ModelSpec("retrieval", "retrieval", (vis, txt), cos)
    classify = ModelSpec("classify", "classification", (vis,), cls)
    vqa = ModelSpec("vqa", "vqa-dec", (vis, txt), lm)

    w_cls = jax.random.normal(jax.random.PRNGKey(5), (ccfg.embed_dim, 10))
    w_lm = jax.random.normal(jax.random.PRNGKey(6),
                             (2 * ccfg.embed_dim, 32)) * 0.3

    def lm_apply(p, enc):
        h = jnp.concatenate([enc["vision"], enc["text"]], -1)
        return jnp.argmax(h @ p, -1)        # toy "answer tokens"

    builders = {
        "mini-vit": lambda: (partial(C.encode_image, cfg=ccfg), params["vision"]),
        "mini-trf": lambda: (partial(C.encode_text, cfg=ccfg), params["text"]),
        "cosine": lambda: (
            lambda p, enc: C.retrieval_logits(enc["vision"], enc["text"], p),
            params["logit_scale"]),
        "mini-classifier": lambda: (lambda p, enc: enc["vision"] @ p, w_cls),
        "mini-lm": lambda: (lm_apply, w_lm),
    }

    # ---- one facade call chain: admit -> plan -> materialize ----
    pool = ClusterSpec(devices=[
        DeviceSpec(f"dev{i}", 1 * GB, (2.0 if i < 2 else 1.0) * 1e9)
        for i in range(min(4, len(devs)))
    ])
    dep = (Deployment(pool)
           .add_model(retrieval, builders)
           .add_model(classify)
           .add_model(vqa)
           .plan(placement="greedy", routing="paper")
           .materialize())

    report = dep.report()
    print("\n" + report.summary())
    print(f"\nHBM ledger: shared={report.shared_bytes:,} B vs "
          f"dedicated={report.dedicated_bytes:,} B "
          f"(saving {report.sharing_savings:.1%})")

    # ---- static pre-flight: prove the plan sound before serving ----
    # materialize()/serve() run this automatically and raise PlanError on
    # ERROR findings; calling verify() directly returns the diagnostics.
    from repro.analysis import format_report
    from repro.analysis.plan_check import check_plan

    print(f"\nverify(): {format_report(dep.verify()).splitlines()[-1]}")
    import copy

    tampered = copy.deepcopy(dep.placement)
    tampered.module_bytes["mini-vit"] = 10**12   # pretend a 1 TB encoder
    finding = check_plan(tampered, pool, dep.models)[0]
    print(f"tampered ledger is rejected statically -> {finding.code} "
          f"[{finding.entity}]")

    # ---- the same Request drives prediction AND real compute ----
    rng = jax.random.PRNGKey(1)
    patches = jax.random.normal(rng, (4, ccfg.n_image_tokens,
                                      ccfg.vision_width))
    ids = jax.random.randint(jax.random.PRNGKey(2), (4, 12), 0,
                             ccfg.vocab_size)
    workload = [
        Request(0, "retrieval", "dev0",
                inputs={"vision": patches, "text": ids}),
        Request(1, "classify", "dev0", inputs={"vision": patches}),
        Request(2, "vqa", "dev0", inputs={"vision": patches, "text": ids}),
    ]

    predicted = dep.simulate(workload)
    for req in workload:
        res = dep.submit(req)
        print(f"\n{req.model}: latency {res.latency_s*1e3:.1f} ms, "
              f"output shape {getattr(res.output, 'shape', None)}")
        print(f"  sim route  {predicted.routes[req.rid]}")
        print(f"  real route {res.devices}")
        t0 = min(t for _, _, t, _ in res.timeline)
        for mod, phase, a, b in res.timeline:
            bar = " " * int((a - t0) * 200) + "#" * max(1, int((b - a) * 200))
            print(f"  {mod:16s} {phase:7s} |{bar}")

    # equivalence: split == monolithic (paper Q3)
    mono = C.clip_forward(params, patches, ids, ccfg)
    split = dep.submit(workload[0]).output
    print(f"\nsplit-vs-monolithic max |diff|: "
          f"{float(jnp.max(jnp.abs(split - mono))):.2e}  (Q3: identical)")

    # ---- continuous batching: shared encoders share COMPUTE too ----
    # requests from all three tasks coalesce into one mini-vit batch
    burst = [Request(10 + i, ["retrieval", "classify", "vqa"][i % 3], "dev0",
                     inputs=(workload[i % 3].inputs), slo_deadline=2.0)
             for i in range(9)]
    served = dep.serve(burst, max_batch=8)
    print(f"\nserve(): {len(served)} requests drained through the "
          f"scheduler; {dep.scheduler.cross_task_batches} cross-task "
          f"batch(es) formed at shared encoders")
    for mod, st in dep.scheduler.stats_dict().items():
        print(f"  {mod:16s} calls={st['calls']:<3d} "
              f"occupancy(mean)={st['mean_occupancy']:<5} "
              f"max_batch={st['max_batch']} "
              f"cross_task={st['cross_task_batches']}")
    same = jnp.max(jnp.abs(served[0].output - dep.submit(burst[0]).output))
    print(f"  batched-vs-solo max |diff|: {float(same):.2e}")

    # ---- observability: SLO attainment, trace export, drift ----
    from repro.obs import format_slo_summary, slo_summary

    print("\nper-task latency / SLO attainment (2 s deadline):")
    print(format_slo_summary(slo_summary(dep.scheduler)))

    trace = dep.trace()
    assert trace.validate() == [], "serve trace must be contiguous trees"
    trace.save("multi_task_trace.json")
    print(f"\nwrote {len(trace)} spans across {len(trace.rids())} request "
          "tracks to multi_task_trace.json (open in chrome://tracing)")

    # did serve() do what simulate() promised?  Same Requests, both paths.
    drift = dep.compare(burst, max_batch=8)
    print("\n" + drift.summary())
    assert drift.n_route_divergences == 0, "sim routes == real devices"

    # ---- lifecycle: hot-remove a task, then a device ----
    freed = dep.evict("vqa")
    print(f"\nevict vqa frees {freed} (shared encoders survive)")
    rep = dep.replan(pool.without("dev0"))
    print(f"replan without dev0: migrations {rep.migrations}")
    print(f"retrieval still serves: "
          f"{dep.submit(workload[0]).devices}")


if __name__ == "__main__":
    main()
