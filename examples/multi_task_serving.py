"""S2M3 end-to-end serving driver (the paper's scenario, real compute).

Sets up 8 logical devices, plans a module placement with the greedy
Algorithm 1, deploys THREE multi-modal tasks that share encoders
(retrieval / classification / VQA with a tiny LM head), serves batched
requests through the engine, and prints the Fig.-3-style timeline plus
the sharing ledger.

    PYTHONPATH=src python examples/multi_task_serving.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.s2m3_zoo import get_clip_config
from repro.core.cluster import ClusterSpec, DeviceSpec
from repro.core.module import ModelSpec, ModuleSpec, distinct_modules
from repro.core.placement import greedy_place
from repro.models import clip as C
from repro.serving.engine import S2M3Engine

GB = 1024**3


def main():
    devs = jax.devices()
    print(f"{len(devs)} devices available")

    # ---- module & model specs (Table II in miniature) ----
    ccfg = get_clip_config("mini-clip")
    params = C.init_clip(jax.random.PRNGKey(0), ccfg)
    lm_head_dim = ccfg.embed_dim

    vis = ModuleSpec("mini-vit", "encoder", "vision", 60_000,
                     flops_per_query=2e6)
    txt = ModuleSpec("mini-trf", "encoder", "text", 50_000,
                     flops_per_query=1e6)
    cos = ModuleSpec("cosine", "head", "task", 0)
    cls = ModuleSpec("mini-classifier", "head", "task", 1_000,
                     flops_per_query=1e4)
    lm = ModuleSpec("mini-lm", "head", "task", 80_000, flops_per_query=4e6)

    retrieval = ModelSpec("retrieval", "retrieval", (vis, txt), cos)
    classify = ModelSpec("classify", "classification", (vis,), cls)
    vqa = ModelSpec("vqa", "vqa-dec", (vis, txt), lm)
    models = [retrieval, classify, vqa]

    # ---- placement over the device pool (Algorithm 1) ----
    pool = ClusterSpec(devices=[
        DeviceSpec(f"dev{i}", 1 * GB, (2.0 if i < 2 else 1.0) * 1e9)
        for i in range(min(4, len(devs)))
    ])
    placement = greedy_place(models, pool)
    print("\ngreedy placement (module -> device):")
    for mod, hosts in placement.assignment.items():
        print(f"  {mod:16s} -> {hosts}")

    # ---- deploy through the engine (sharing dedups) ----
    device_map = {d.name: devs[i % len(devs)]
                  for i, d in enumerate(pool.devices)}
    engine = S2M3Engine(device_map)
    w_cls = jax.random.normal(jax.random.PRNGKey(5), (ccfg.embed_dim, 10))
    w_lm = jax.random.normal(jax.random.PRNGKey(6),
                             (2 * ccfg.embed_dim, 32)) * 0.3

    def lm_apply(p, enc):
        h = jnp.concatenate([enc["vision"], enc["text"]], -1)
        return jnp.argmax(h @ p, -1)        # toy "answer tokens"

    builders = {
        "mini-vit": lambda: (partial(C.encode_image, cfg=ccfg), params["vision"]),
        "mini-trf": lambda: (partial(C.encode_text, cfg=ccfg), params["text"]),
        "cosine": lambda: (
            lambda p, enc: C.retrieval_logits(enc["vision"], enc["text"], p),
            params["logit_scale"]),
        "mini-classifier": lambda: (lambda p, enc: enc["vision"] @ p, w_cls),
        "mini-lm": lambda: (lm_apply, w_lm),
    }
    for mdl in models:
        loaded = engine.deploy_model(mdl, builders, placement)
        print(f"deploy {mdl.name:10s}: loaded {loaded or '(all reused!)'}")

    print(f"\nHBM ledger: shared={engine.deployed_bytes():,} B vs "
          f"dedicated={engine.dedicated_bytes():,} B "
          f"(saving {1 - engine.deployed_bytes()/engine.dedicated_bytes():.1%})")

    # ---- serve requests across the three tasks ----
    rng = jax.random.PRNGKey(1)
    patches = jax.random.normal(rng, (4, ccfg.n_image_tokens,
                                      ccfg.vision_width))
    ids = jax.random.randint(jax.random.PRNGKey(2), (4, 12), 0,
                             ccfg.vocab_size)
    for task, inputs in [
        ("retrieval", {"vision": patches, "text": ids}),
        ("classify", {"vision": patches}),
        ("vqa", {"vision": patches, "text": ids}),
    ]:
        res = engine.infer(task, inputs)
        print(f"\n{task}: latency {res.latency_s*1e3:.1f} ms, "
              f"output shape {getattr(res.output, 'shape', None)}")
        t0 = min(t for _, _, t, _ in res.timeline)
        for mod, phase, a, b in res.timeline:
            bar = " " * int((a - t0) * 200) + "#" * max(1, int((b - a) * 200))
            print(f"  {mod:16s} {phase:7s} |{bar}")

    # equivalence: split == monolithic (paper Q3)
    mono = C.clip_forward(params, patches, ids, ccfg)
    split = engine.infer("retrieval", {"vision": patches, "text": ids}).output
    print(f"\nsplit-vs-monolithic max |diff|: "
          f"{float(jnp.max(jnp.abs(split - mono))):.2e}  (Q3: identical)")


if __name__ == "__main__":
    main()
