"""End-to-end training driver: train a small LM on the synthetic corpus
with checkpointing and crash-resume.

    PYTHONPATH=src python examples/train_lm.py --steps 100 \
        [--params 10m|100m] [--ckpt /tmp/ckpt] [--resume]

The 100m preset is the assignment's "~100M model for a few hundred
steps" configuration; the 10m preset finishes quickly on this 1-core
box (the paper's kind is serving, so the required end-to-end driver is
examples/multi_task_serving.py — this one exercises the training
substrate end to end).
"""

import argparse
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.common.config import ArchConfig, TrainConfig
from repro.models.api import build_model
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, TokenStream
from repro.training.optimizer import init_state
from repro.training.train_step import make_train_step

PRESETS = {
    "10m": ArchConfig(name="lm-10m", family="dense", n_layers=4, d_model=256,
                      n_heads=4, n_kv_heads=2, d_ff=1024, vocab_size=8192),
    "100m": ArchConfig(name="lm-100m", family="dense", n_layers=12,
                       d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                       vocab_size=32000),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--params", default="10m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = PRESETS[args.params]
    bundle = build_model(cfg, compute_dtype=jnp.float32)
    print(f"{cfg.name}: {bundle.param_count():,} params")
    tcfg = TrainConfig(learning_rate=6e-4, warmup_steps=20,
                       total_steps=args.steps, remat="none")
    state = init_state(bundle.init(jax.random.PRNGKey(0)), tcfg)

    ckdir = pathlib.Path(args.ckpt) / cfg.name
    if args.resume and ckpt.latest_step(ckdir) is not None:
        state = ckpt.restore(state, ckdir)
        print(f"resumed from step {int(state['step'])}")

    step_fn = jax.jit(make_train_step(bundle, tcfg), donate_argnums=(0,))
    data = TokenStream(DataConfig(seq_len=args.seq, global_batch=args.batch,
                                  vocab_size=cfg.vocab_size))
    start = int(state["step"])
    t0 = time.time()
    for i, batch in zip(range(start, args.steps), data):
        state, metrics = step_fn(state, {k: jnp.asarray(v)
                                         for k, v in batch.items()})
        if (i + 1) % 10 == 0:
            tok_s = args.batch * args.seq * (i + 1 - start) / (time.time() - t0)
            print(f"step {i+1:4d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.2f}  {tok_s:,.0f} tok/s")
        if (i + 1) % args.ckpt_every == 0:
            ckpt.save(state, ckdir, step=i + 1)
            print(f"  checkpointed step {i+1}")
    ckpt.save(state, ckdir, step=int(state["step"]))
    print("done; final checkpoint at", ckdir)


if __name__ == "__main__":
    main()
