"""Reproduce the paper's testbed numbers through the ``s2m3.Deployment``
facade and render the Fig. 3 timeline.

    PYTHONPATH=src python examples/edge_placement_sim.py
"""

from repro.core.module import distinct_modules
from repro.core.profiles import install_profile, make_testbed
from repro.core.routing import timeline_ascii
from repro.core.zoo import paper_zoo, request_for
from repro.s2m3 import Deployment


def main():
    zoo = paper_zoo()
    clip = zoo["clip-vit-b/16"]
    cluster = make_testbed(with_server=True)
    install_profile(cluster, distinct_modules(list(zoo.values())).values())
    edge = cluster.without("server")
    reqs = [request_for(clip, 0, "jetson-a")]

    print("== CLIP ViT-B/16, image-text retrieval (paper Table VII) ==")
    dep = Deployment(edge).add_model(clip).plan("greedy", routing="paper")
    print(f"greedy placement: {dep.placement.assignment}")
    res = dep.simulate(reqs)
    print(f"S2M3 edge-only:     {res.mean_latency:6.2f} s  (paper 2.48)")
    central = Deployment(cluster).add_model(clip)
    for dev, paper in [("server", 2.44), ("desktop", 3.46),
                       ("laptop", 3.02), ("jetson-a", 45.19)]:
        t = central.plan("centralized", routing="paper",
                         device=dev).simulate(reqs).mean_latency
        print(f"centralized {dev:10s}: {t:6.2f} s  (paper {paper})")
    t_up = dep.plan("optimal", routing="paper",
                    workload=reqs).simulate(reqs).mean_latency
    print(f"Upper (brute force): {t_up:6.2f} s")

    print("\n== Fig. 3 timeline (S2M3, edge-only) ==")
    print(timeline_ascii(res.sim))

    print("\n== Table X: incremental multi-task deployment ==")
    multi = Deployment(edge)
    for name in ("clip-vit-b/16", "encoder-only-vqa-s", "alignment-vit-b",
                 "clip-cls-vit-b/16"):
        before = set(multi.registry.modules)
        multi.add_model(zoo[name])
        new = [m for m in multi.registry.modules if m not in before]
        print(f"+{name:22s} loads {new or 'NOTHING (all shared)'}"
              f" -> total {multi.registry.shared_bytes()/4/1e6:.0f}M params "
              f"(dedicated would be {multi.registry.dedicated_bytes()/4/1e6:.0f}M)")
    report = multi.plan("greedy", routing="paper").report()
    print(f"sharing saving: {report.sharing_savings:.1%}  (paper: 61.5%)")


if __name__ == "__main__":
    main()
