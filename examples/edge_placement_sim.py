"""Reproduce the paper's testbed numbers with the placement/routing
simulator and render the Fig. 3 timeline.

    PYTHONPATH=src python examples/edge_placement_sim.py
"""

from repro.core.module import distinct_modules
from repro.core.placement import centralized_place, greedy_place, optimal_place
from repro.core.profiles import install_profile, make_testbed
from repro.core.registry import ModuleRegistry
from repro.core.routing import simulate, timeline_ascii
from repro.core.zoo import paper_zoo, request_for


def main():
    zoo = paper_zoo()
    clip = zoo["clip-vit-b/16"]
    cluster = make_testbed(with_server=True)
    install_profile(cluster, distinct_modules(list(zoo.values())).values())
    edge = cluster.without("server")
    reqs = [request_for(clip, 0, "jetson-a")]

    print("== CLIP ViT-B/16, image-text retrieval (paper Table VII) ==")
    pl = greedy_place([clip], edge)
    print(f"greedy placement: {pl.assignment}")
    res = simulate(reqs, pl, edge, [clip])
    print(f"S2M3 edge-only:     {res.mean_latency:6.2f} s  (paper 2.48)")
    for dev, paper in [("server", 2.44), ("desktop", 3.46),
                       ("laptop", 3.02), ("jetson-a", 45.19)]:
        plc = centralized_place([clip], cluster, dev)
        t = simulate(reqs, plc, cluster, [clip]).mean_latency
        print(f"centralized {dev:10s}: {t:6.2f} s  (paper {paper})")
    _, t_up = optimal_place([clip], edge, reqs)
    print(f"Upper (brute force): {t_up:6.2f} s")

    print("\n== Fig. 3 timeline (S2M3, edge-only) ==")
    print(timeline_ascii(res))

    print("\n== Table X: incremental multi-task deployment ==")
    reg = ModuleRegistry()
    for name in ("clip-vit-b/16", "encoder-only-vqa-s", "alignment-vit-b",
                 "clip-cls-vit-b/16"):
        new = reg.add_model(zoo[name])
        print(f"+{name:22s} loads {[m.name for m in new] or 'NOTHING (all shared)'}"
              f" -> total {reg.shared_bytes()/4/1e6:.0f}M params "
              f"(dedicated would be {reg.dedicated_bytes()/4/1e6:.0f}M)")
    print(f"sharing saving: {reg.sharing_savings():.1%}  (paper: 61.5%)")


if __name__ == "__main__":
    main()
