"""Unit tests for the layer library."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import ArchConfig
from repro.layers import attention as attn
from repro.layers.initializers import WSpec, abstract_tree, init_tree, stack_specs
from repro.layers.mlp import mlp_apply, mlp_specs
from repro.layers.norms import apply_norm, norm_specs
from repro.layers.rope import apply_rope


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=32, n_heads=4,
                n_kv_heads=2, d_ff=64, vocab_size=64, head_dim=8)
    base.update(kw)
    return ArchConfig(**base)


def test_rmsnorm_matches_manual():
    params = init_tree(jax.random.PRNGKey(0), norm_specs(16))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 16))
    y = apply_norm(params, x, "rmsnorm", 1e-6)
    manual = x / np.sqrt((np.asarray(x) ** 2).mean(-1, keepdims=True) + 1e-6)
    np.testing.assert_allclose(np.asarray(y), manual * np.asarray(params["scale"]),
                               rtol=1e-5)


def test_layernorm_zero_mean_unit_var():
    params = init_tree(jax.random.PRNGKey(0), norm_specs(16, "layernorm"))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16)) * 5 + 3
    y = np.asarray(apply_norm(params, x, "layernorm", 1e-6))
    np.testing.assert_allclose(y.mean(-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(y.var(-1), 1.0, rtol=1e-3)


def test_rope_preserves_norm_and_relativity():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 6, 2, 8))
    pos = jnp.arange(6)[None, :]
    y = apply_rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)
    # dot products depend only on relative distance
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 8))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 8))
    def dot_at(pq, pk):
        qq = apply_rope(q, jnp.array([[pq]]))
        kk = apply_rope(k, jnp.array([[pk]]))
        return float(jnp.sum(qq * kk))
    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-4


def test_gqa_matches_explicit_repeat():
    B, S, H, K, D = 2, 8, 4, 2, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, K, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, K, D))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    out = attn.gqa_scores(q, k, v, q_positions=pos, kv_positions=pos)
    from repro.kernels.ref import flash_attention_ref

    expect = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)


def test_sliding_window_masks_far_tokens():
    B, S, H, D = 1, 16, 2, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, D))
    v = jnp.eye(S)[None, :, None, :8].repeat(H, 2)  # positional signature
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    out_w = attn.gqa_scores(q, k, v, q_positions=pos, kv_positions=pos,
                            window=4)
    # query at t can only see keys in (t-4, t]: rows of v beyond are zero
    contrib = np.asarray(out_w)[0, -1, 0]   # last query
    # v one-hot on first 8 dims: tokens 0..7; all outside window (12..15]
    assert np.allclose(contrib[:8], 0.0, atol=1e-5)


def test_softcap_bounds_logits():
    x = jnp.array([1000.0, -1000.0, 0.0])
    capped = attn._softcap(x, 50.0)
    assert float(jnp.max(jnp.abs(capped))) <= 50.0


def test_mlp_swiglu():
    params = init_tree(jax.random.PRNGKey(0), mlp_specs(8, 16))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 8))
    y = mlp_apply(params, x, "silu")
    g = np.asarray(x) @ np.asarray(params["wi_gate"])
    u = np.asarray(x) @ np.asarray(params["wi_up"])
    h = g / (1 + np.exp(-g)) * u
    np.testing.assert_allclose(np.asarray(y), h @ np.asarray(params["wo"]),
                               rtol=2e-4, atol=1e-5)


def test_stack_specs_prepends_layer_axis():
    specs = stack_specs(mlp_specs(8, 16), 5)
    assert specs["wi_gate"].shape == (5, 8, 16)
    assert specs["wi_gate"].axes[0] == "layers"
    tree = abstract_tree(specs)
    assert tree["wo"].shape == (5, 16, 8)
