"""Per-arch REQUIRED smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs; plus decode<->prefill
consistency (the serving contract)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import get_config, list_archs
from repro.models.api import build_model

ARCHS = list_archs()


def _batch(cfg, B=2, S=16, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    text = S - (cfg.n_image_tokens if cfg.has_vision_stub else 0)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, text), 0, cfg.vocab_size),
        "targets": jax.random.randint(ks[1], (B, text), 0, cfg.vocab_size),
        "mask": jnp.ones((B, text), jnp.float32),
    }
    if cfg.has_vision_stub:
        batch["image_embeds"] = 0.1 * jax.random.normal(
            ks[2], (B, cfg.n_image_tokens, cfg.d_model))
    if cfg.is_encoder_decoder:
        batch["audio_frames"] = 0.1 * jax.random.normal(
            ks[3], (B, cfg.encoder_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    expected = {
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 49155),
        "deepseek-v3-671b": (61, 7168, 128, 128, 129280),
        "gemma2-9b": (42, 3584, 16, 8, 256000),
        "llama3-8b": (32, 4096, 32, 8, 128256),
        "tinyllama-1.1b": (22, 2048, 32, 4, 32000),
        "llama3-405b": (126, 16384, 128, 8, 128256),
        "internvl2-1b": (24, 896, 14, 2, 151655),
        "whisper-tiny": (4, 384, 6, 6, 51865),
        "zamba2-7b": (81, 3584, 32, 32, 32000),
        "xlstm-1.3b": (48, 2048, 4, 4, 50304),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.vocab_size)
    assert got == expected, (arch, got, expected)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    m = build_model(cfg, compute_dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(m.loss_fn)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_grads_finite(arch):
    cfg = get_config(arch, smoke=True)
    m = build_model(cfg, compute_dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, B=1, S=16)
    g = jax.jit(jax.grad(lambda p: m.loss_fn(p, batch)[0]))(params)
    for path_leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(path_leaf)).all(), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    """Teacher-forcing consistency: decoding token S given cache from a
    prefill of S tokens must equal a fresh prefill over S+1 tokens."""
    cfg = get_config(arch, smoke=True)
    m = build_model(cfg, compute_dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(0))
    B, S, T = 2, 8, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab_size)
    extra = {}
    if cfg.has_vision_stub:
        extra["image_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.n_image_tokens, cfg.d_model))
    if cfg.is_encoder_decoder:
        extra["audio_frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(4), (B, cfg.encoder_seq, cfg.d_model))
    n_pref = cfg.n_image_tokens if cfg.has_vision_stub else 0

    cache = m.init_cache(B, T, dtype=jnp.float32)
    _, cache = jax.jit(m.prefill)(params, {"tokens": toks[:, :S], **extra},
                                  cache)
    lengths = jnp.full((B,), S + n_pref, jnp.int32)
    logits, _ = jax.jit(m.decode_step)(params, toks[:, S:], cache, lengths)

    cache2 = m.init_cache(B, T, dtype=jnp.float32)
    logits_ref, _ = jax.jit(m.prefill)(
        params, {"tokens": toks[:, : S + 1], **extra}, cache2)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_ref),
                               rtol=5e-4, atol=5e-4)


def test_moe_active_params_less_than_total():
    for arch in ("granite-moe-3b-a800m", "deepseek-v3-671b"):
        m = build_model(get_config(arch, smoke=True))
        assert m.active_param_count() < m.param_count()
