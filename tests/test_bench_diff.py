"""benchmarks/diff.py — snapshot regression comparator."""

import importlib.util
import json
import sys
from pathlib import Path

_DIFF = Path(__file__).resolve().parents[1] / "benchmarks" / "diff.py"
_spec = importlib.util.spec_from_file_location("bench_diff", _DIFF)
bench_diff = importlib.util.module_from_spec(_spec)
sys.modules["bench_diff"] = bench_diff
_spec.loader.exec_module(bench_diff)


def _snap(rows, section="serving"):
    return {"section": section, "rows": rows}


def test_no_regression_within_threshold():
    old = _snap([{"name": "submit", "p50_ms": 10.0, "p99_ms": 20.0}])
    new = _snap([{"name": "submit", "p50_ms": 11.0, "p99_ms": 22.0}])
    regs, notes = bench_diff.diff_snapshots(old, new)   # +10% < 1.20x
    assert regs == [] and notes == []


def test_regression_beyond_threshold_flagged():
    old = _snap([{"name": "submit", "p50_ms": 10.0, "p99_ms": 20.0}])
    new = _snap([{"name": "submit", "p50_ms": 10.5, "p99_ms": 50.0}])
    regs, _ = bench_diff.diff_snapshots(old, new)
    assert [(r.row, r.metric) for r in regs] == [("submit", "p99_ms")]
    assert regs[0].ratio == 2.5
    assert "REGRESSION" in regs[0].format()


def test_threshold_configurable():
    old = _snap([{"name": "a", "wall_s": 1.0}])
    new = _snap([{"name": "a", "wall_s": 1.15}])
    assert bench_diff.diff_snapshots(old, new)[0] == []
    regs, _ = bench_diff.diff_snapshots(old, new, threshold=1.10)
    assert len(regs) == 1


def test_improvements_and_row_churn_are_notes_not_failures():
    old = _snap([{"name": "a", "p50_ms": 10.0},
                 {"name": "gone", "p50_ms": 1.0}])
    new = _snap([{"name": "a", "p50_ms": 2.0},
                 {"name": "fresh", "p50_ms": 1.0}])
    regs, notes = bench_diff.diff_snapshots(old, new)
    assert regs == []
    assert any("improvement a.p50_ms" in n for n in notes)
    assert any("'gone' removed" in n for n in notes)
    assert any("'fresh' added" in n for n in notes)


def test_metric_coverage_change_is_noted():
    old = _snap([{"name": "a", "p50_ms": 10.0, "p99_ms": 20.0}])
    new = _snap([{"name": "a", "p50_ms": 10.0}])
    _, notes = bench_diff.diff_snapshots(old, new)
    assert any("a.p99_ms present in only one snapshot" in n for n in notes)


def test_non_latency_keys_ignored():
    old = _snap([{"name": "a", "p50_ms": 10.0, "throughput_rps": 100.0}])
    new = _snap([{"name": "a", "p50_ms": 10.0, "throughput_rps": 1.0}])
    regs, notes = bench_diff.diff_snapshots(old, new)
    assert regs == [] and notes == []


def _write(path, snap, machine=None):
    snap = dict(snap)
    snap["machine"] = (bench_diff.machine_profile()
                       if machine is None else machine)
    path.write_text(json.dumps(snap))
    return path


def test_cli_exit_codes(tmp_path, capsys):
    old = _write(tmp_path / "old.json",
                 _snap([{"name": "a", "p50_ms": 10.0}]))
    new = _write(tmp_path / "new.json",
                 _snap([{"name": "a", "p50_ms": 100.0}]))
    assert bench_diff.main([str(old), str(new)]) == 1
    assert "REGRESSION a.p50_ms" in capsys.readouterr().out
    assert bench_diff.main([str(old), str(old)]) == 0


# ---- machine-profile guard ----------------------------------------------

def test_machine_profile_has_identity_keys():
    prof = bench_diff.machine_profile()
    assert {"platform", "python", "jax"} <= set(prof)
    assert bench_diff.profile_mismatches(prof, dict(prof)) == []


def test_cross_machine_comparison_refused(tmp_path, capsys):
    rows = [{"name": "a", "p50_ms": 10.0}]
    other = dict(bench_diff.machine_profile(),
                 platform="Linux-0.0-other-box", device_kind="TPU v9000")
    old = _write(tmp_path / "old.json", _snap(rows), machine=other)
    new = _write(tmp_path / "new.json", _snap(rows))
    assert bench_diff.main([str(old), str(new)]) == 2
    out = capsys.readouterr().out
    assert "refusing cross-machine comparison" in out
    assert "platform" in out
    # explicit override still compares
    assert bench_diff.main(["--ignore-machine", str(old), str(new)]) == 0


def test_snapshot_without_profile_header_refused(tmp_path, capsys):
    p = tmp_path / "bare.json"
    p.write_text(json.dumps(_snap([{"name": "a", "p50_ms": 1.0}])))
    q = _write(tmp_path / "ok.json", _snap([{"name": "a", "p50_ms": 1.0}]))
    assert bench_diff.main([str(p), str(q)]) == 2
    assert "no machine profile header" in capsys.readouterr().out


# ---- clear messages instead of tracebacks -------------------------------

def test_missing_file_is_message_not_traceback(tmp_path, capsys):
    ok = _write(tmp_path / "ok.json", _snap([{"name": "a", "p50_ms": 1.0}]))
    assert bench_diff.main([str(tmp_path / "nope.json"), str(ok)]) == 2
    assert "does not exist" in capsys.readouterr().out


def test_unreadable_json_is_message_not_traceback(tmp_path, capsys):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    ok = _write(tmp_path / "ok.json", _snap([{"name": "a", "p50_ms": 1.0}]))
    assert bench_diff.main([str(bad), str(ok)]) == 2
    assert "not readable JSON" in capsys.readouterr().out


def test_section_mismatch_is_refused(tmp_path, capsys):
    old = _write(tmp_path / "old.json",
                 _snap([{"name": "a", "p50_ms": 1.0}], section="kernels"))
    new = _write(tmp_path / "new.json",
                 _snap([{"name": "a", "p50_ms": 1.0}], section="serving"))
    assert bench_diff.main([str(old), str(new)]) == 2
    assert "section mismatch" in capsys.readouterr().out


def test_disjoint_row_names_are_refused(tmp_path, capsys):
    old = _write(tmp_path / "old.json",
                 _snap([{"name": "a", "p50_ms": 1.0}]))
    new = _write(tmp_path / "new.json",
                 _snap([{"name": "b", "p50_ms": 1.0}]))
    assert bench_diff.main([str(old), str(new)]) == 2
    assert "share no row names" in capsys.readouterr().out


def test_real_snapshot_self_diff_is_clean():
    snap = Path(__file__).resolve().parents[1] / "BENCH_serving.json"
    if not snap.exists():
        import pytest

        pytest.skip("no committed serving snapshot")
    data = json.loads(snap.read_text())
    regs, notes = bench_diff.diff_snapshots(data, data)
    assert regs == [] and notes == []
