"""S2M3 on TPU sub-meshes: pod partitioning + roofline t_comp, and the
request-level work multiplicity semantics."""

import pytest

from repro.core.cluster import DeviceSpec
from repro.core.module import ModelSpec, ModuleSpec
from repro.core.placement import greedy_place
from repro.core.routing import Request, simulate, work_multiplier
from repro.core.tpu import install_roofline_profile, pod_cluster, roofline_t_comp
from repro.core.zoo import arch_model_spec, paper_zoo


def test_pod_cluster_partitions():
    cluster = pod_cluster([64, 64, 64, 64])
    assert len(cluster.devices) == 4
    assert all(d.kind == "submesh" for d in cluster.devices)
    # 64 chips x 16 GiB each
    assert cluster.devices[0].mem_capacity == 64 * 16 * 1024**3
    # ICI inter-submesh links exist and are fast
    t = cluster.t_comm(cluster.devices[0].name, cluster.devices[1].name, 1e9)
    assert t < 0.01


def test_roofline_t_comp_picks_binding_term():
    small_hot = ModuleSpec("hot", "encoder", "vision", int(1e6),
                           flops_per_query=1e15)   # compute-bound
    big_cold = ModuleSpec("cold", "head", "task", int(20e9),
                          flops_per_query=1e9)     # memory-bound
    t_hot = roofline_t_comp(small_hot, n_chips=64)
    t_cold = roofline_t_comp(big_cold, n_chips=64)
    assert t_hot == pytest.approx(1e15 / (64 * 197e12))
    assert t_cold == pytest.approx(40e9 / (64 * 819e9))


def test_s2m3_places_paper_zoo_on_a_pod():
    """The paper's whole 14-model zoo fits one 256-chip pod split 4 ways,
    with every module placed and sharing deduped."""
    zoo = paper_zoo()
    models = list(zoo.values())
    cluster = pod_cluster([64, 64, 64, 64])
    install_roofline_profile(
        cluster,
        {m.name: m for mdl in models for m in mdl.modules}.values())
    pl = greedy_place(models, cluster)
    assert pl.feasible
    res = simulate([Request(0, "llava-v1.5-13b", cluster.devices[0].name)],
                   pl, cluster, models)
    assert res.feasible and res.mean_latency < 1.0   # sub-second on a pod


def test_assigned_archs_place_alongside_zoo():
    from repro.common.config import get_config

    zoo = paper_zoo()
    extra = [arch_model_spec(get_config("internvl2-1b")),
             arch_model_spec(get_config("whisper-tiny"))]
    models = list(zoo.values()) + extra
    cluster = pod_cluster([128, 64, 64])
    install_roofline_profile(
        cluster,
        {m.name: m for mdl in models for m in mdl.modules}.values())
    pl = greedy_place(models, cluster)
    assert pl.feasible
    res = simulate([Request(0, "internvl2-1b", cluster.devices[0].name)],
                   pl, cluster, models)
    assert res.feasible


def test_work_multiplier_semantics():
    req = Request(0, "m", "a", work=(("text", 100.0),))
    batched = DeviceSpec("gpu", 1, 1e9, extra_work_factor=0.1)
    serial = DeviceSpec("pi", 1, 1e9, extra_work_factor=1.0)
    assert work_multiplier(req, "text", batched) == pytest.approx(10.9)
    assert work_multiplier(req, "text", serial) == pytest.approx(100.0)
    assert work_multiplier(req, "vision", serial) == 1.0
