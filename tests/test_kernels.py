"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode + hypothesis on decode lengths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                                  # property tests need hypothesis; the
    import hypothesis.strategies as st   # rest of the file runs without it
    from hypothesis import given, settings
except ModuleNotFoundError:           # pragma: no cover - minimal install
    st = None

from repro.kernels import ops, ref

TOLS = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
        jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,K,D,bq,bk", [
    (1, 32, 2, 2, 16, 16, 16),
    (2, 64, 4, 2, 32, 16, 32),     # GQA 2:1
    (1, 128, 8, 1, 16, 32, 32),    # MQA
    (2, 64, 4, 4, 64, 64, 16),     # MHA, tall blocks
])
def test_flash_attention_sweep(B, S, H, K, D, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, K, D), dtype)
    v = jax.random.normal(ks[2], (B, S, K, D), dtype)
    out = ops.flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
    expect = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        **TOLS[dtype])


@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (False, 0, 0.0), (True, 16, 0.0), (True, 8, 50.0),
])
def test_flash_attention_variants(causal, window, softcap):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 64, 2, 16))
    k = jax.random.normal(ks[1], (2, 64, 2, 16))
    v = jax.random.normal(ks[2], (2, 64, 2, 16))
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, block_q=16, block_k=16,
                              interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                     softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


if st is not None:
    @settings(max_examples=10, deadline=None)
    @given(l1=st.integers(1, 64), l2=st.integers(1, 64))
    def test_decode_attention_random_lengths(l1, l2):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        B, H, K, D, T = 2, 4, 2, 16, 64
        q = jax.random.normal(ks[0], (B, H, D))
        k = jax.random.normal(ks[1], (B, T, K, D))
        v = jax.random.normal(ks[2], (B, T, K, D))
        lengths = jnp.array([l1, l2], jnp.int32)
        out = ops.decode_attention(q, k, v, lengths, block_k=16,
                                   interpret=True)
        expect = ref.decode_attention_ref(q, k, v, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-4, atol=2e-4)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_decode_attention_random_lengths():
        pass


def _paged_from_contiguous(k, v, page_size, *, seed=0, spare=1):
    """Scatter contiguous (B,T,K,D) caches into a shuffled page pool;
    returns (k_pages, v_pages, block_tables)."""
    B, T, K, D = k.shape
    n_max = -(-T // page_size)
    n_pages = B * n_max + spare
    perm = np.random.default_rng(seed).permutation(n_pages - 1) + 1
    tables = np.asarray(perm[:B * n_max].reshape(B, n_max), np.int32)
    kp = np.zeros((n_pages, page_size, K, D), np.float32)
    vp = np.zeros((n_pages, page_size, K, D), np.float32)
    for b in range(B):
        for j in range(n_max):
            lo = j * page_size
            sl = np.asarray(k[b, lo:lo + page_size])
            kp[tables[b, j], :sl.shape[0]] = sl
            vp[tables[b, j], :sl.shape[0]] = np.asarray(
                v[b, lo:lo + page_size])
    return jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(tables)


@pytest.mark.parametrize("B,H,K,D,T,ps,softcap", [
    (2, 4, 2, 16, 64, 16, 0.0),    # GQA 2:1
    (1, 8, 1, 16, 48, 8, 0.0),     # MQA, ragged last page
    (2, 4, 4, 32, 64, 16, 30.0),   # MHA + logit softcap
])
def test_paged_decode_attention_matches_oracles(B, H, K, D, T, ps, softcap):
    """The batched paged kernel == its paged oracle == the contiguous
    decode oracle over the same logical cache (pages shuffled)."""
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, T, K, D))
    v = jax.random.normal(ks[2], (B, T, K, D))
    lengths = jnp.asarray([T - i * 7 - 1 for i in range(B)], jnp.int32)
    kp, vp, tables = _paged_from_contiguous(k, v, ps)
    out = ops.paged_decode_attention(q, kp, vp, tables, lengths,
                                     softcap=softcap, interpret=True)
    paged_ref = ref.paged_decode_attention_ref(q, kp, vp, tables, lengths,
                                               softcap=softcap)
    contig_ref = ref.decode_attention_ref(q, k, v, lengths, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(paged_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(contig_ref),
                               rtol=2e-4, atol=2e-4)


if st is not None:
    @settings(max_examples=8, deadline=None)
    @given(l1=st.integers(1, 64), l2=st.integers(1, 64))
    def test_paged_decode_attention_random_lengths(l1, l2):
        ks = jax.random.split(jax.random.PRNGKey(5), 3)
        B, H, K, D, T, ps = 2, 4, 2, 16, 64, 16
        q = jax.random.normal(ks[0], (B, H, D))
        k = jax.random.normal(ks[1], (B, T, K, D))
        v = jax.random.normal(ks[2], (B, T, K, D))
        lengths = jnp.array([l1, l2], jnp.int32)
        kp, vp, tables = _paged_from_contiguous(k, v, ps, seed=l1 * 65 + l2)
        out = ops.paged_decode_attention(q, kp, vp, tables, lengths,
                                         interpret=True)
        expect = ref.decode_attention_ref(q, k, v, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-4, atol=2e-4)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_paged_decode_attention_random_lengths():
        pass


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 32, 2, 8, 4, 8),
    (2, 64, 3, 8, 4, 16),
    (1, 128, 1, 16, 8, 32),
])
def test_ssd_kernel_sweep(B, S, H, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    Bm = jax.random.normal(ks[1], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[2], (B, S, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    A_log = jnp.linspace(-1.0, 0.0, H)
    y, fin = ops.ssd_chunked(x, Bm, Cm, dt, A_log, chunk=chunk,
                             interpret=True)
    ye, fe = ref.ssd_chunk_ref(x, Bm, Cm, dt, A_log)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(fe),
                               rtol=3e-4, atol=3e-4)


def test_ssd_kernel_state_continuation():
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    B, S, H, P, N = 1, 64, 2, 8, 4
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    Bm = jax.random.normal(ks[1], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[2], (B, S, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    A_log = jnp.zeros((H,))
    _, s1 = ops.ssd_chunked(x[:, :32], Bm[:, :32], Cm[:, :32], dt[:, :32],
                            A_log, chunk=16, interpret=True)
    y2, s2 = ops.ssd_chunked(x[:, 32:], Bm[:, 32:], Cm[:, 32:], dt[:, 32:],
                             A_log, chunk=16, initial_state=s1,
                             interpret=True)
    y_full, s_full = ops.ssd_chunked(x, Bm, Cm, dt, A_log, chunk=16,
                                     interpret=True)
    np.testing.assert_allclose(np.asarray(y_full[:, 32:]), np.asarray(y2),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("B,S,H,hd,bs", [
    (1, 16, 2, 8, 8),
    (2, 32, 2, 8, 8),
    (2, 32, 4, 4, 16),
])
def test_slstm_scan_kernel(B, S, H, hd, bs):
    d = H * hd
    pre = jax.random.normal(jax.random.PRNGKey(0), (B, S, 4, d)) * 0.5
    R = jax.random.normal(jax.random.PRNGKey(1), (4, H, hd, hd)) * 0.2
    out = ops.slstm_scan(pre, R, block_s=bs, interpret=True)
    expect = ref.slstm_cell_ref(pre, R)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_slstm_scan_kernel_matches_layer_cell():
    """The kernel's cell equations == layers.xlstm.slstm_apply's scan."""
    from repro.common.config import ArchConfig
    from repro.layers import xlstm as xl
    from repro.layers.initializers import init_tree

    cfg = ArchConfig(name="x", family="ssm", n_layers=1, d_model=16,
                     n_heads=2, n_kv_heads=2, d_ff=0, vocab_size=16)
    params = init_tree(jax.random.PRNGKey(0), xl.slstm_specs(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    # pre-activations exactly as slstm_apply computes them (post-ln)
    from repro.layers.norms import apply_norm

    xn = apply_norm(params["ln"], x, cfg.norm, cfg.norm_eps).astype(jnp.float32)
    pre = jnp.stack([
        jnp.einsum("bsd,de->bse", xn, params[f"w_{g}"].astype(jnp.float32))
        + params[f"b_{g}"].astype(jnp.float32)
        for g in ("i", "f", "z", "o")], axis=2)
    R = jnp.stack([params[f"r_{g}"] for g in ("i", "f", "z", "o")])
    h_kernel = ops.slstm_scan(pre, R, block_s=4, interpret=True)
    # oracle: the layer's own recurrence, pre-FFN (reconstruct from ref)
    h_ref = ref.slstm_cell_ref(pre, R)
    np.testing.assert_allclose(np.asarray(h_kernel), np.asarray(h_ref),
                               rtol=1e-5, atol=1e-5)
