"""Serving: continuous batching == sequential generation; slot reuse;
S2M3 engine split/share semantics with real computation."""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import get_config
from repro.configs.s2m3_zoo import get_clip_config
from repro.core.module import ModelSpec, ModuleSpec
from repro.models import clip as C
from repro.models.api import build_model
from repro.serving.engine import S2M3Engine
from repro.serving.generator import GenRequest, LMServer


def _reference_generate(bundle, params, prompt, n_new, cache_len=64):
    """Sequential greedy decoding oracle."""
    cache = bundle.init_cache(1, cache_len, dtype=jnp.float32)
    logits, cache = jax.jit(bundle.prefill)(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)}, cache)
    out = [int(jnp.argmax(logits[0]))]
    length = len(prompt)
    for _ in range(n_new - 1):
        logits, cache = jax.jit(bundle.decode_step)(
            params, jnp.asarray([[out[-1]]], jnp.int32), cache,
            jnp.asarray([length], jnp.int32))
        length += 1
        out.append(int(jnp.argmax(logits[0])))
    return out


def test_continuous_batching_matches_sequential():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    bundle = build_model(cfg, compute_dtype=jnp.float32)
    params = bundle.init(jax.random.PRNGKey(0))
    server = LMServer(bundle, max_batch=3, cache_len=64, params=params)

    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [10], [11, 12]]
    for i, p in enumerate(prompts):
        server.submit(GenRequest(rid=i, prompt=p, max_new_tokens=6))
    finished = server.run()
    assert len(finished) == len(prompts)

    for req in finished:
        expect = _reference_generate(bundle, params, req.prompt, 6)
        assert req.output == expect, (req.rid, req.output, expect)


def test_slot_reuse_under_pressure():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    bundle = build_model(cfg, compute_dtype=jnp.float32)
    server = LMServer(bundle, max_batch=2, cache_len=32)
    for i in range(5):     # more requests than slots
        server.submit(GenRequest(rid=i, prompt=[i + 1], max_new_tokens=4))
    finished = server.run()
    assert len(finished) == 5
    assert server.pool.n_live == 0


def test_engine_split_equals_monolithic():
    ccfg = get_clip_config("mini-clip")
    params = C.init_clip(jax.random.PRNGKey(0), ccfg)
    vis = ModuleSpec("mini-vit", "encoder", "vision", 1000)
    txt = ModuleSpec("mini-trf", "encoder", "text", 1000)
    head = ModuleSpec("cosine", "head", "task", 0)
    model = ModelSpec("retrieval", "retrieval", (vis, txt), head)
    engine = S2M3Engine()
    engine.deploy_model(model, {
        "mini-vit": lambda: (partial(C.encode_image, cfg=ccfg), params["vision"]),
        "mini-trf": lambda: (partial(C.encode_text, cfg=ccfg), params["text"]),
        "cosine": lambda: (
            lambda p, enc: C.retrieval_logits(enc["vision"], enc["text"], p),
            params["logit_scale"]),
    })
    patches = jax.random.normal(jax.random.PRNGKey(1),
                                (4, ccfg.n_image_tokens, ccfg.vision_width))
    ids = jax.random.randint(jax.random.PRNGKey(2), (4, 12), 0,
                             ccfg.vocab_size)
    res = engine.infer("retrieval", {"vision": patches, "text": ids})
    mono = C.clip_forward(params, patches, ids, ccfg)
    np.testing.assert_array_equal(np.asarray(res.output), np.asarray(mono))


def test_engine_shares_modules_across_tasks():
    ccfg = get_clip_config("mini-clip")
    params = C.init_clip(jax.random.PRNGKey(0), ccfg)
    vis = ModuleSpec("mini-vit", "encoder", "vision", 1000)
    txt = ModuleSpec("mini-trf", "encoder", "text", 1000)
    builders = {
        "mini-vit": lambda: (partial(C.encode_image, cfg=ccfg), params["vision"]),
        "mini-trf": lambda: (partial(C.encode_text, cfg=ccfg), params["text"]),
        "cosine": lambda: (
            lambda p, enc: C.retrieval_logits(enc["vision"], enc["text"], p),
            params["logit_scale"]),
        "cls": lambda: (lambda p, enc: enc["vision"] @ p,
                        jnp.ones((ccfg.embed_dim, 7))),
    }
    engine = S2M3Engine()
    m1 = ModelSpec("retrieval", "retrieval", (vis, txt),
                   ModuleSpec("cosine", "head", "task", 0))
    m2 = ModelSpec("classify", "classification", (vis,),
                   ModuleSpec("cls", "head", "task", 100))
    loaded1 = engine.deploy_model(m1, builders)
    loaded2 = engine.deploy_model(m2, builders)
    assert "mini-vit" in loaded1 and "mini-vit" not in loaded2
    # eviction keeps shared modules alive while referenced
    freed = engine.evict_model("retrieval")
    assert "mini-vit" not in freed        # still used by classify
    freed = engine.evict_model("classify")
    assert "mini-vit" in freed


def test_vlm_server_with_image_stub():
    cfg = get_config("internvl2-1b", smoke=True)
    bundle = build_model(cfg, compute_dtype=jnp.float32)
    server = LMServer(bundle, max_batch=2, cache_len=64)
    img = 0.1 * np.random.default_rng(0).standard_normal(
        (cfg.n_image_tokens, cfg.d_model)).astype(np.float32)
    server.submit(GenRequest(rid=0, prompt=[1, 2, 3], max_new_tokens=4,
                             extras={"image_embeds": img}))
    finished = server.run()
    assert len(finished) == 1 and len(finished[0].output) == 4
