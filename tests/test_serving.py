"""Serving: paged continuous batching == sequential generation; page
and row reuse; S2M3 engine split/share semantics with real computation."""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import get_config
from repro.configs.s2m3_zoo import get_clip_config
from repro.core.module import ModelSpec, ModuleSpec
from repro.core.routing import Request
from repro.models import clip as C
from repro.models.api import build_model
from repro.serving.engine import S2M3Engine
from repro.serving.scheduler import SchedulerConfig, lm_scheduler


def _reference_generate(bundle, params, prompt, n_new, cache_len=64):
    """Sequential greedy decoding oracle (dense contiguous cache)."""
    cache = bundle.init_cache(1, cache_len, dtype=jnp.float32)
    logits, cache = jax.jit(bundle.prefill)(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)}, cache)
    out = [int(jnp.argmax(logits[0]))]
    length = len(prompt)
    for _ in range(n_new - 1):
        logits, cache = jax.jit(bundle.decode_step)(
            params, jnp.asarray([[out[-1]]], jnp.int32), cache,
            jnp.asarray([length], jnp.int32))
        length += 1
        out.append(int(jnp.argmax(logits[0])))
    return out


@pytest.fixture(scope="module")
def tinyllama():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    bundle = build_model(cfg, compute_dtype=jnp.float32)
    params = bundle.init(jax.random.PRNGKey(0))
    return cfg, bundle, params


def test_continuous_batching_matches_sequential(tinyllama):
    cfg, bundle, params = tinyllama
    sched = lm_scheduler(bundle, params, config=SchedulerConfig(
        decode_rows=3, page_size=8, max_seq_len=64, decode_pages=25))

    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [10], [11, 12]]
    reqs = [Request(rid=i, model="lm", source="dev0", prompt=tuple(p),
                    max_new_tokens=6) for i, p in enumerate(prompts)]
    results = sched.serve(reqs)
    assert len(results) == len(prompts)

    for req, res in zip(reqs, results):
        expect = _reference_generate(bundle, params, list(req.prompt), 6)
        assert list(res.output) == expect, (req.rid, list(res.output), expect)


def test_row_and_page_reuse_under_pressure(tinyllama):
    cfg, bundle, params = tinyllama
    # 2 rows, pool sized for barely 2 worst-case sequences: the 5
    # requests must recycle rows AND pages to finish
    sched = lm_scheduler(bundle, params, config=SchedulerConfig(
        decode_rows=2, page_size=8, max_seq_len=32, decode_pages=9))
    reqs = [Request(rid=i, model="lm", source="dev0", prompt=(i + 1,),
                    max_new_tokens=4) for i in range(5)]
    results = sched.serve(reqs)
    assert len(results) == 5
    assert all(len(r.output) == 4 for r in results)
    stream = sched.decode[cfg.name]
    assert stream.rows.n_live == 0
    assert stream.pool.n_seqs == 1            # only the dummy page owner
    assert stream.pool.n_live_pages == 1
    st = sched.stats_dict()[cfg.name]
    assert st["decode_tokens"] == 15          # 5 req * (4 - 1 prefill tok)
    assert st["pages_peak"] >= 3


def test_generative_results_stream_as_they_finish(tinyllama):
    cfg, bundle, params = tinyllama
    order = []
    sched = lm_scheduler(bundle, params,
                         on_finish=lambda r: order.append(r.rid),
                         config=SchedulerConfig(
                             decode_rows=4, page_size=8, max_seq_len=64,
                             decode_pages=33))
    reqs = [Request(rid=i, model="lm", source="dev0", prompt=(1, 2),
                    max_new_tokens=n) for i, n in enumerate((9, 2, 5))]
    sched.serve(reqs)
    # shorter decodes finish (and stream) first, not in admission order
    assert order == [1, 2, 0]


def test_vlm_captioning_through_scheduler():
    cfg = get_config("internvl2-1b", smoke=True)
    bundle = build_model(cfg, compute_dtype=jnp.float32)
    params = bundle.init(jax.random.PRNGKey(0))
    sched = lm_scheduler(bundle, params, config=SchedulerConfig(
        decode_rows=2, page_size=8, max_seq_len=64, decode_pages=17))
    img = 0.1 * np.random.default_rng(0).standard_normal(
        (cfg.n_image_tokens, cfg.d_model)).astype(np.float32)
    reqs = [Request(rid=0, model="lm", source="dev0", prompt=(1, 2, 3),
                    max_new_tokens=4, inputs={"vision": img})]
    results = sched.serve(reqs)
    assert len(results) == 1 and len(results[0].output) == 4
    # solo oracle over the same engine: identical tokens
    solo = sched.engine.generate(reqs[0])
    assert list(results[0].output) == list(solo.output)


def test_engine_split_equals_monolithic():
    ccfg = get_clip_config("mini-clip")
    params = C.init_clip(jax.random.PRNGKey(0), ccfg)
    vis = ModuleSpec("mini-vit", "encoder", "vision", 1000)
    txt = ModuleSpec("mini-trf", "encoder", "text", 1000)
    head = ModuleSpec("cosine", "head", "task", 0)
    model = ModelSpec("retrieval", "retrieval", (vis, txt), head)
    engine = S2M3Engine()
    engine.deploy_model(model, {
        "mini-vit": lambda: (partial(C.encode_image, cfg=ccfg), params["vision"]),
        "mini-trf": lambda: (partial(C.encode_text, cfg=ccfg), params["text"]),
        "cosine": lambda: (
            lambda p, enc: C.retrieval_logits(enc["vision"], enc["text"], p),
            params["logit_scale"]),
    })
    patches = jax.random.normal(jax.random.PRNGKey(1),
                                (4, ccfg.n_image_tokens, ccfg.vision_width))
    ids = jax.random.randint(jax.random.PRNGKey(2), (4, 12), 0,
                             ccfg.vocab_size)
    res = engine.infer("retrieval", {"vision": patches, "text": ids})
    mono = C.clip_forward(params, patches, ids, ccfg)
    np.testing.assert_array_equal(np.asarray(res.output), np.asarray(mono))


def test_engine_shares_modules_across_tasks():
    ccfg = get_clip_config("mini-clip")
    params = C.init_clip(jax.random.PRNGKey(0), ccfg)
    vis = ModuleSpec("mini-vit", "encoder", "vision", 1000)
    txt = ModuleSpec("mini-trf", "encoder", "text", 1000)
    builders = {
        "mini-vit": lambda: (partial(C.encode_image, cfg=ccfg), params["vision"]),
        "mini-trf": lambda: (partial(C.encode_text, cfg=ccfg), params["text"]),
        "cosine": lambda: (
            lambda p, enc: C.retrieval_logits(enc["vision"], enc["text"], p),
            params["logit_scale"]),
        "cls": lambda: (lambda p, enc: enc["vision"] @ p,
                        jnp.ones((ccfg.embed_dim, 7))),
    }
    engine = S2M3Engine()
    m1 = ModelSpec("retrieval", "retrieval", (vis, txt),
                   ModuleSpec("cosine", "head", "task", 0))
    m2 = ModelSpec("classify", "classification", (vis,),
                   ModuleSpec("cls", "head", "task", 100))
    loaded1 = engine.deploy_model(m1, builders)
    loaded2 = engine.deploy_model(m2, builders)
    assert "mini-vit" in loaded1 and "mini-vit" not in loaded2
    # eviction keeps shared modules alive while referenced
    freed = engine.evict_model("retrieval")
    assert "mini-vit" not in freed        # still used by classify
    freed = engine.evict_model("classify")
    assert "mini-vit" in freed
