"""Placement: Algorithm 1 greedy, brute-force Upper, invariants
(property-based via hypothesis)."""

import pytest

try:                                  # property tests need hypothesis; the
    import hypothesis.strategies as st   # rest of the file runs without it
    from hypothesis import given, settings
except ModuleNotFoundError:           # pragma: no cover - minimal install
    st = None

from repro.core.cluster import ClusterSpec, DeviceSpec
from repro.core.module import ModelSpec, ModuleSpec
from repro.core.placement import (
    centralized_place, greedy_place, optimal_place, replan,
)
from repro.core.routing import Request, simulate


def _enc(name, mb, flops=1e9):
    return ModuleSpec(name, "encoder", "vision", int(mb / 2),
                      flops_per_query=flops)


def _head(name, mb=0, flops=0.0):
    return ModuleSpec(name, "head", "task", int(mb / 2),
                      flops_per_query=flops)


def _cluster(caps, speeds):
    return ClusterSpec(devices=[
        DeviceSpec(f"d{i}", c, s) for i, (c, s) in enumerate(zip(caps, speeds))
    ])


def test_greedy_respects_memory():
    m = ModelSpec("m", "t", (_enc("e1", 100), _enc("e2", 100)), _head("h", 100))
    cluster = _cluster([120, 120, 120], [1e9, 1e9, 1e9])
    pl = greedy_place([m], cluster)
    assert pl.feasible
    for d in cluster.devices:
        assert pl.bytes_on(d.name, {x.name: x for x in m.modules}) <= d.mem_capacity


def test_greedy_infeasible_detection():
    m = ModelSpec("m", "t", (_enc("e1", 1000),), _head("h"))
    cluster = _cluster([100], [1e9])
    pl = greedy_place([m], cluster)
    assert not pl.feasible and "e1" in pl.infeasible_modules


def test_greedy_places_big_module_on_fast_device():
    big = _enc("big", 400, flops=100e9)
    small = _enc("small", 50, flops=1e9)
    m = ModelSpec("m", "t", (big, small), _head("h"))
    cluster = _cluster([1000, 1000], [10e9, 1e9])  # d0 is 10x faster
    pl = greedy_place([m], cluster)
    assert pl.assignment["big"] == ["d0"]


def test_sharing_dedups_placement():
    shared = _enc("shared-vit", 100)
    m1 = ModelSpec("m1", "a", (shared,), _head("h1"))
    m2 = ModelSpec("m2", "b", (shared,), _head("h2"))
    cluster = _cluster([150, 150], [1e9, 1e9])
    pl = greedy_place([m1, m2], cluster, share=True)
    assert len(pl.assignment["shared-vit"]) == 1
    pl_ns = greedy_place([m1, m2], cluster, share=False)
    hosted = [k for k in pl_ns.assignment if k.startswith("shared-vit")]
    assert len(hosted) == 2   # a dedicated copy per model


def test_replication_fills_leftover_memory():
    m = ModelSpec("m", "t", (_enc("e1", 100),), _head("h", 10))
    cluster = _cluster([500, 500], [1e9, 1e9])
    pl = greedy_place([m], cluster, replicate=True)
    assert len(pl.assignment["e1"]) == 2


def test_centralized_infeasible_on_small_device():
    m = ModelSpec("m", "t", (_enc("e1", 300),), _head("h", 300))
    cluster = _cluster([100], [1e9])
    pl = centralized_place([m], cluster, "d0")
    assert not pl.feasible


def test_greedy_close_to_bruteforce():
    """Paper: greedy hits optimal in 89/95 instances; assert within 10%
    on a deterministic instance and exact on the easy one."""
    m = ModelSpec("m", "t", (_enc("e1", 100, 20e9), _enc("e2", 50, 5e9)),
                  _head("h", 1, 1e6))
    cluster = _cluster([200, 200, 60], [2e9, 1e9, 0.5e9])
    reqs = [Request(i, "m", "d2", arrival=float(i)) for i in range(3)]
    pl_g = greedy_place([m], cluster)
    t_g = simulate(reqs, pl_g, cluster, [m]).total_latency
    pl_o, t_o = optimal_place([m], cluster, reqs)
    assert t_o <= t_g <= 1.10 * t_o


def test_optimal_place_guard_rejects_large_instances():
    """Regression: the max_nodes enumeration guard was a no-op ``pass``;
    oversized instances must fail fast instead of enumerating |N|^|M|."""
    m = ModelSpec("m", "t", (_enc("e1", 10), _enc("e2", 10)), _head("h"))
    cluster = _cluster([1000] * 3, [1e9] * 3)
    reqs = [Request(0, "m", "d0")]
    # 3 modules x 3 devices = 9 > max_nodes*8 when max_nodes=1
    with pytest.raises(ValueError, match="max_nodes"):
        optimal_place([m], cluster, reqs, max_nodes=1)
    # the default budget admits the same instance
    pl, t = optimal_place([m], cluster, reqs)
    assert pl.feasible and t < float("inf")


def test_replan_reports_migrations():
    m = ModelSpec("m", "t", (_enc("e1", 100, 20e9),), _head("h", 1))
    c1 = _cluster([200, 200], [1e9, 2e9])
    pl1 = greedy_place([m], c1)
    c2 = c1.without("d1")     # fast device leaves
    pl2, migrations = replan([m], c1, c2, pl1)
    assert pl2.feasible
    assert all(dev == "d0" for _, dev in migrations) or not migrations


# ---- property-based invariants ------------------------------------------

if st is not None:
    module_sizes = st.lists(st.integers(1, 50), min_size=1, max_size=6)
    device_caps = st.lists(st.integers(10, 200), min_size=1, max_size=5)

    @settings(max_examples=60, deadline=None)
    @given(sizes=module_sizes, caps=device_caps, seed=st.integers(0, 10_000))
    def test_greedy_invariants(sizes, caps, seed):
        import random

        rng = random.Random(seed)
        encs = tuple(
            _enc(f"e{i}", mb, flops=rng.uniform(1e8, 1e10))
            for i, mb in enumerate(sizes))
        m = ModelSpec("m", "t", encs[:-1] or encs, _head("h", sizes[-1]))
        cluster = _cluster(caps, [rng.uniform(1e8, 1e10) for _ in caps])
        pl = greedy_place([m], cluster)
        mods = {x.name: x for x in m.modules}
        # memory constraint always holds
        for d in cluster.devices:
            assert pl.bytes_on(d.name, mods) <= d.mem_capacity
        # every module either placed exactly once or reported infeasible
        for name in mods:
            placed = len(pl.assignment.get(name, []))
            if name in pl.infeasible_modules:
                assert placed == 0 and not pl.feasible
            else:
                assert placed == 1
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_greedy_invariants():
        pass
