"""Reduced-mesh dry-run in a subprocess (the only place allowed to force
a multi-device host): proves lower+compile works for a (2,2) and a
(2,2,2) multi-pod mesh over the same machinery as launch/dryrun.py."""

import json
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
from repro.common.config import get_config, ShapeConfig, TrainConfig
from repro.common.sharding import merge_rules, tree_shardings
from repro.common.hlo_cost import analyze
from repro.layers.initializers import abstract_tree
from repro.models.api import build_model
from repro.training.optimizer import state_specs
from repro.training.train_step import make_train_step

multi_pod = %(multi_pod)s
if multi_pod:
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
else:
    mesh = jax.make_mesh((2, 2), ("data", "model"))
cfg = get_config("%(arch)s", smoke=True)
rules = merge_rules(None)
bundle = build_model(cfg, mesh=mesh, rules=rules)
tcfg = TrainConfig()
ss = state_specs(bundle.specs, tcfg)
sds = abstract_tree(ss, jnp.float32, tree_shardings(ss, rules, mesh))
shape = ShapeConfig("t", "train", 32, 8)
bs = bundle.batch_specs(shape)
bsds = abstract_tree(bs, jnp.bfloat16, tree_shardings(bs, rules, mesh))
step = make_train_step(bundle, tcfg)
with mesh:
    compiled = jax.jit(step, donate_argnums=(0,)).lower(sds, bsds).compile()
rep = analyze(compiled.as_text())
ma = compiled.memory_analysis()
print(json.dumps({
    "flops": rep.flops,
    "collective_bytes": rep.collective_bytes,
    "temp": int(ma.temp_size_in_bytes),
}))
"""


def _run(arch, multi_pod):
    code = SCRIPT % {"arch": arch, "multi_pod": multi_pod}
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
        timeout=600, cwd=str(REPO))
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "granite-moe-3b-a800m"])
def test_small_mesh_dryrun(arch):
    rec = _run(arch, multi_pod=False)
    assert rec["flops"] > 0
    assert rec["collective_bytes"] > 0     # gradient sync must appear
    assert rec["temp"] > 0


def test_small_multipod_dryrun():
    rec = _run("tinyllama-1.1b", multi_pod=True)
    assert rec["flops"] > 0
    assert rec["collective_bytes"] > 0
