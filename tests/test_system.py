"""End-to-end behaviour: the paper's headline claims, reproduced.

Each test pins one row of EXPERIMENTS.md to executable form:
  Q1 split memory savings (Table VI/VII), Q2 latency vs cloud/local
  (Table VII), Q3 accuracy preserved = split produces identical outputs,
  Q4 multi-task sharing (Table X), greedy ~ optimal (the 89/95 claim),
  device-availability scaling (Table IX).
"""

import jax
import pytest

from repro.core.module import distinct_modules
from repro.core.placement import centralized_place, greedy_place, optimal_place
from repro.core.profiles import install_profile, make_testbed
from repro.core.registry import ModuleRegistry
from repro.core.routing import simulate
from repro.core.zoo import arch_model_spec, paper_zoo, request_for

ZOO = paper_zoo()


def _cluster(with_server=True):
    cluster = make_testbed(with_server=with_server)
    install_profile(cluster, distinct_modules(list(ZOO.values())).values())
    return cluster


def test_q1_split_reduces_single_device_memory():
    clip = ZOO["clip-vit-b/16"]
    assert clip.max_module_bytes < clip.total_bytes
    saving = 1 - clip.max_module_bytes / clip.total_bytes
    assert saving >= 0.30            # paper: 31% for ViT-B/16


def test_q2_s2m3_within_15pct_of_cloud_and_10x_faster_than_jetson():
    cluster = _cluster()
    clip = ZOO["clip-vit-b/16"]
    reqs = [request_for(clip, 0, "jetson-a")]
    edge = cluster.without("server")
    t_s2m3 = simulate(reqs, greedy_place([clip], edge), edge,
                      [clip]).mean_latency
    t_cloud = simulate(reqs, centralized_place([clip], cluster, "server"),
                       cluster, [clip]).mean_latency
    t_local = simulate(reqs, centralized_place([clip], cluster, "jetson-a"),
                       cluster, [clip]).mean_latency
    assert t_s2m3 <= 1.15 * t_cloud      # paper: 2.48 vs 2.44
    assert t_s2m3 * 10 < t_local         # paper: 2.48 vs 45.19


def test_q2_parallel_beats_no_parallel():
    """Table VII: S2M3 2.48s vs 3.03s without parallel processing."""
    cluster = _cluster(with_server=False)
    clip = ZOO["clip-vit-b/16"]
    pl = greedy_place([clip], cluster)
    from repro.core.routing import work_multiplier

    req = request_for(clip, 0, "jetson-a")
    res = simulate([req], pl, cluster, [clip])
    t_parallel = res.mean_latency
    dev_of = {m: d[0] for m, d in pl.assignment.items()}
    t_serial = sum(
        cluster.comp_table[(m.name, dev_of[m.name])]
        * work_multiplier(req, m.modality, cluster.device(dev_of[m.name]))
        for m in clip.encoders)
    assert t_parallel < t_serial + 0.5


def test_q3_split_outputs_identical():
    """Accuracy is untouched because the split model computes the same
    function — asserted bit-exactly in test_serving.py; here we assert
    the zoo decomposition matches the paper's Table II."""
    clip = ZOO["clip-vit-b/16"]
    assert {m.name for m in clip.modules} == \
        {"vit-b/16", "clip-trf-38m", "cosine-similarity"}


def test_q4_multi_task_sharing_targets_paper_number():
    reg = ModuleRegistry()
    for name in ("clip-vit-b/16", "encoder-only-vqa-s", "alignment-vit-b",
                 "clip-cls-vit-b/16"):
        reg.add_model(ZOO[name])
    assert 0.55 <= reg.sharing_savings() <= 0.68   # paper: 61.5%


def test_greedy_matches_bruteforce_on_testbed():
    """The 89/95 claim, in miniature: greedy == optimal for the default
    single-model testbed instance."""
    cluster = _cluster(with_server=False)
    clip = ZOO["clip-vit-b/16"]
    reqs = [request_for(clip, 0, "jetson-a")]
    pl_g = greedy_place([clip], cluster)
    t_g = simulate(reqs, pl_g, cluster, [clip]).total_latency
    _, t_o = optimal_place([clip], cluster, reqs)
    assert t_g <= 1.05 * t_o


def test_table_ix_server_accelerates_s2m3():
    """S2M3 + server beats edge-only S2M3 (paper: 1.74 < 2.48)."""
    cluster = _cluster(with_server=True)
    clip = ZOO["clip-vit-b/16"]
    reqs = [request_for(clip, 0, "jetson-a")]
    edge = cluster.without("server")
    t_edge = simulate(reqs, greedy_place([clip], edge), edge,
                      [clip]).mean_latency
    t_plus = simulate(reqs, greedy_place([clip], cluster), cluster,
                      [clip]).mean_latency
    assert t_plus < t_edge


def test_assigned_archs_participate_in_sharing():
    """tinyllama-1.1b (assigned arch) shares its LM with the paper's
    Flint-v0.5-1B head — cross-registry sharing actually triggers."""
    from repro.common.config import get_config

    reg = ModuleRegistry()
    reg.add_model(ZOO["flint-v0.5-1b"])
    spec = arch_model_spec(get_config("tinyllama-1.1b", smoke=False))
    new = reg.add_model(spec)
    assert reg.refcount("tinyllama-1.1b") == 2
    assert all(m.name != "tinyllama-1.1b" for m in new)


def test_jetson_cannot_host_but_split_makes_it_feasible():
    """Table VI '-' rows: models too big for one Jetson become feasible
    under split placement across the pool."""
    cluster = _cluster(with_server=False)
    big = ZOO["imagebind"]
    pl_local = centralized_place([big], cluster, "jetson-a")
    assert not pl_local.feasible
    pl_split = greedy_place([big], cluster)
    assert pl_split.feasible
