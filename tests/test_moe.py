"""MoE: dense-masked oracle vs expert-parallel shard_map path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import ArchConfig
from repro.common.sharding import local_mesh
from repro.layers.initializers import init_tree
from repro.layers.moe import (
    moe_apply_dense, moe_apply_ep, moe_specs, padded_experts,
)


def _cfg(n_experts=6, pad=0, k=2, shared=0):
    return ArchConfig(
        name="m", family="moe", n_layers=1, d_model=16, n_heads=2,
        n_kv_heads=2, d_ff=32, vocab_size=32, n_experts=n_experts,
        experts_top_k=k, moe_d_ff=32, expert_pad_to=pad,
        n_shared_experts=shared,
    )


def test_ep_matches_dense_with_ample_capacity():
    cfg = _cfg()
    params = init_tree(jax.random.PRNGKey(0), moe_specs(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
    mesh = local_mesh((1, 1))
    y_d, aux_d = moe_apply_dense(params, x, cfg)
    y_e, aux_e = jax.jit(
        lambda p, xx: moe_apply_ep(p, xx, cfg, mesh, capacity_factor=8.0)
    )(params, x)
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_e),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_d), float(aux_e), rtol=1e-4)


def test_padded_experts_never_selected():
    cfg = _cfg(n_experts=5, pad=8)
    assert padded_experts(cfg) == 8
    params = init_tree(jax.random.PRNGKey(0), moe_specs(cfg))
    assert params["router"].shape == (16, 5)       # router sees real experts
    assert params["wi_gate"].shape == (8, 16, 32)  # weights padded
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 16))
    mesh = local_mesh((1, 1))
    y_d, _ = moe_apply_dense(params, x, cfg)
    y_e, _ = moe_apply_ep(params, x, cfg, mesh, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_e),
                               rtol=2e-4, atol=2e-4)


def test_shared_expert_added():
    cfg0, cfg1 = _cfg(shared=0), _cfg(shared=1)
    p1 = init_tree(jax.random.PRNGKey(0), moe_specs(cfg1))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 16))
    y1, _ = moe_apply_dense(p1, x, cfg1)
    p0 = {k: v for k, v in p1.items() if k != "shared"}
    y0, _ = moe_apply_dense(p0, x, cfg0)
    assert not np.allclose(np.asarray(y0), np.asarray(y1))


def test_ep_gradients_finite():
    cfg = _cfg()
    params = init_tree(jax.random.PRNGKey(0), moe_specs(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 16))
    mesh = local_mesh((1, 1))

    def loss(p):
        y, aux = moe_apply_ep(p, x, cfg, mesh, capacity_factor=8.0)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(params)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
    # routed experts receive gradient
    assert float(jnp.abs(g["wi_gate"]).max()) > 0


def test_aux_loss_balanced_router_is_minimal():
    cfg = _cfg(n_experts=4, k=1)
    params = init_tree(jax.random.PRNGKey(0), moe_specs(cfg))
    # uniform router -> aux ~= 1.0 (its minimum is 1 for balanced load)
    params["router"] = jnp.zeros_like(params["router"])
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16))
    _, aux = moe_apply_dense(params, x, cfg)
    assert 0.9 < float(aux) < 1.6
