"""Mamba2 SSD and xLSTM: chunked-parallel vs recurrent oracle equality,
and state continuation (the prefill->decode contract)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.layers import mamba2 as m2
from repro.layers import xlstm as xl


def _ssd_inputs(key, B=2, S=32, H=3, P=8, N=4):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    Bm = jax.random.normal(ks[1], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[2], (B, S, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    A_log = jnp.linspace(-1.0, 0.5, H)
    D = jnp.ones((H,))
    return x, Bm, Cm, dt, A_log, D


@pytest.mark.parametrize("chunk", [4, 8, 32, 31])
def test_ssd_chunked_matches_recurrent(chunk):
    x, Bm, Cm, dt, A_log, D = _ssd_inputs(jax.random.PRNGKey(0))
    y_c, s_c = m2._ssd_chunked(x, Bm, Cm, dt, A_log, D, chunk)
    y_r, s_r = m2.ssd_recurrent_ref(x, Bm, Cm, dt, A_log, D)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_r),
                               rtol=2e-4, atol=2e-4)


def test_ssd_state_continuation():
    """chunked(full) == chunked(first half) then continue on second half."""
    x, Bm, Cm, dt, A_log, D = _ssd_inputs(jax.random.PRNGKey(1), S=32)
    y_full, s_full = m2._ssd_chunked(x, Bm, Cm, dt, A_log, D, 8)
    y1, s1 = m2._ssd_chunked(x[:, :16], Bm[:, :16], Cm[:, :16], dt[:, :16],
                             A_log, D, 8)
    y2, s2 = m2._ssd_chunked(x[:, 16:], Bm[:, 16:], Cm[:, 16:], dt[:, 16:],
                             A_log, D, 8, initial_state=s1)
    np.testing.assert_allclose(np.asarray(y_full[:, 16:]), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [4, 16, 13])
def test_mlstm_chunked_matches_recurrent(chunk):
    key = jax.random.PRNGKey(2)
    B, S, H, D = 2, 16, 2, 8
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, D))
    i_log = jax.random.normal(ks[3], (B, S, H))
    f_log = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, H)) + 2.0)
    h_c, st_c = xl._mlstm_chunked(q, k, v, i_log, f_log, chunk)
    h_r, st_r = xl.mlstm_recurrent_ref(q, k, v, i_log, f_log)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_r),
                               rtol=3e-4, atol=3e-4)
    for a, b in zip(st_c[:2], st_r[:2]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)


def test_mlstm_state_continuation():
    key = jax.random.PRNGKey(3)
    B, S, H, D = 1, 16, 2, 4
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, D))
    il = jax.random.normal(ks[3], (B, S, H))
    fl = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, H)) + 2.0)
    h_full, _ = xl._mlstm_chunked(q, k, v, il, fl, 4)
    _, st = xl._mlstm_chunked(q[:, :8], k[:, :8], v[:, :8], il[:, :8],
                              fl[:, :8], 4)
    h2, _ = xl._mlstm_chunked(q[:, 8:], k[:, 8:], v[:, 8:], il[:, 8:],
                              fl[:, 8:], 4, state=st)
    np.testing.assert_allclose(np.asarray(h_full[:, 8:]), np.asarray(h2),
                               rtol=3e-4, atol=3e-4)


def test_slstm_decode_continuation():
    """slstm_apply over S steps == step-by-step with carried state."""
    from repro.common.config import ArchConfig
    from repro.layers.initializers import init_tree

    cfg = ArchConfig(name="x", family="ssm", n_layers=1, d_model=16,
                     n_heads=2, n_kv_heads=2, d_ff=0, vocab_size=16)
    params = init_tree(jax.random.PRNGKey(0), xl.slstm_specs(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 16))
    y_full, st_full = xl.slstm_apply(params, x, cfg)
    st = None
    outs = []
    for t in range(6):
        y, st = xl.slstm_apply(params, x[:, t : t + 1], cfg, state=st)
        outs.append(y)
    y_steps = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_steps),
                               rtol=2e-4, atol=2e-4)
