"""Paged KV allocation: PagePool lifecycle (alloc/extend/free), the
double-free guards on both allocators, LIFO page reuse, fragmentation
accounting, block-table views, and the cache scatter helpers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.kvcache import (
    PagePool, PagesExhausted, SlotPool, insert_pages,
)


# ---- SlotPool ------------------------------------------------------------

def test_slotpool_alloc_release_cycle():
    pool = SlotPool(2)
    a, b = pool.alloc(), pool.alloc()
    assert {a, b} == {0, 1}
    assert pool.alloc() is None          # exhausted -> None, not a raise
    assert pool.n_live == 2
    pool.release(a)
    assert pool.n_live == 1
    assert pool.alloc() == a             # LIFO reuse of the freed row


def test_slotpool_double_free_raises():
    pool = SlotPool(2)
    s = pool.alloc()
    pool.release(s)
    with pytest.raises(ValueError, match="double"):
        pool.release(s)
    with pytest.raises(ValueError, match="not live"):
        pool.release(1)                  # never allocated


# ---- PagePool lifecycle --------------------------------------------------

def test_pagepool_alloc_rounds_up_to_pages():
    pool = PagePool(8, page_size=4)
    assert pool.pages_for(0) == 0
    assert pool.pages_for(1) == 1
    assert pool.pages_for(4) == 1
    assert pool.pages_for(5) == 2
    pages = pool.alloc("a", 9)           # 9 tokens -> 3 pages of 4
    assert len(pages) == 3
    assert pool.n_live_pages == 3 and pool.n_free == 5
    assert pool.used_tokens["a"] == 9


def test_pagepool_zero_token_alloc_still_owns_a_page():
    pool = PagePool(4, page_size=4)
    assert len(pool.alloc("a", 0)) == 1  # block table is never empty


def test_pagepool_extend_crosses_page_boundary():
    pool = PagePool(8, page_size=4)
    pool.alloc("a", 3)
    assert pool.extend("a", 4) == []     # tail page still has room
    added = pool.extend("a", 5)          # crosses into page 2
    assert len(added) == 1
    assert pool.block_table("a") != [] and len(pool.block_table("a")) == 2
    assert pool.used_tokens["a"] == 5
    # extend never shrinks the used count
    pool.extend("a", 2)
    assert pool.used_tokens["a"] == 5


def test_pagepool_alloc_twice_same_seq_raises():
    pool = PagePool(4, page_size=4)
    pool.alloc("a", 1)
    with pytest.raises(ValueError, match="already live"):
        pool.alloc("a", 1)


def test_pagepool_double_free_raises():
    pool = PagePool(4, page_size=4)
    pool.alloc("a", 1)
    pool.free("a")
    with pytest.raises(ValueError, match="double"):
        pool.free("a")
    with pytest.raises(ValueError, match="not live"):
        pool.free("never-seen")


def test_pagepool_exhaustion_raises_and_leaves_pool_intact():
    pool = PagePool(3, page_size=4)
    pool.alloc("a", 8)                   # 2 pages
    with pytest.raises(PagesExhausted, match="need 2 pages"):
        pool.alloc("b", 5)               # would need 2, only 1 free
    assert pool.n_free == 1              # failed alloc claimed nothing
    pool.alloc("b", 4)                   # 1 page still fits
    with pytest.raises(PagesExhausted):
        pool.extend("b", 5)
    assert not pool.can_alloc(1)


def test_pagepool_lifo_reuse_after_free():
    """Freed pages are recycled hottest-first: a new sequence gets the
    pages the dead one just released, in the same order."""
    pool = PagePool(8, page_size=4)
    a_pages = pool.alloc("a", 12)
    pool.alloc("b", 4)
    pool.free("a")
    c_pages = pool.alloc("c", 12)
    assert c_pages == a_pages


def test_pagepool_fragmentation_accounting():
    pool = PagePool(8, page_size=4)
    pool.alloc("a", 5)                   # 2 pages, 5/8 tokens used
    pool.alloc("b", 4)                   # 1 page, full
    frag = pool.fragmentation()
    assert frag["pages_live"] == 3
    assert frag["tokens_capacity"] == 12
    assert frag["tokens_used"] == 9
    assert frag["slack_tokens"] == 3
    assert frag["internal_frag"] == pytest.approx(1 - 9 / 12, abs=1e-4)
    assert frag["pages_peak"] == 3
    pool.free("a")
    assert pool.fragmentation()["pages_peak"] == 3   # peak is sticky
    empty = PagePool(4, page_size=4).fragmentation()
    assert empty["internal_frag"] == 0.0


# ---- block-table views ---------------------------------------------------

def test_table_array_pads_and_guards_overflow():
    pool = PagePool(8, page_size=4)
    pool.alloc("a", 8)                   # 2 pages
    pool.alloc("b", 1)                   # 1 page
    arr = pool.table_array(["a", "b", "ghost"], n_max=3)
    assert arr.shape == (3, 3) and arr.dtype == np.int32
    assert list(arr[0, :2]) == pool.block_table("a")
    assert arr[0, 2] == 0 and arr[1, 1] == 0         # padded
    assert (arr[2] == 0).all()                       # unknown seq -> zeros
    with pytest.raises(ValueError, match="n_max"):
        pool.table_array(["a"], n_max=1)


def test_block_table_is_a_copy():
    pool = PagePool(4, page_size=4)
    pool.alloc("a", 4)
    view = pool.block_table("a")
    view.append(99)
    assert pool.block_table("a") != view


# ---- cache scatter helpers -----------------------------------------------

def test_insert_pages_scatters_dense_prefill_into_pool():
    layers, n_pages, ps, heads, dim = 2, 6, 4, 2, 3
    paged = {"k": jnp.zeros((layers, n_pages, ps, heads, dim))}
    T = 8
    dense = {"k": jnp.arange(layers * T * heads * dim, dtype=jnp.float32)
                  .reshape(layers, 1, T, heads, dim)}
    pages = [4, 1]                       # deliberately non-contiguous
    out = insert_pages(paged, dense, pages, n_tokens=T)
    got = np.asarray(out["k"])
    want = np.asarray(dense["k"][:, 0])
    for j, pid in enumerate(pages):
        np.testing.assert_array_equal(got[:, pid],
                                      want[:, j * ps:(j + 1) * ps])
    # untouched pages stay zero
    for pid in set(range(n_pages)) - set(pages):
        assert (got[:, pid] == 0).all()
