"""Data pipeline: determinism, host sharding, learnable structure."""

import numpy as np
import pytest

from repro.training.data import DataConfig, TokenStream, write_token_file


def test_deterministic_across_instances():
    d = DataConfig(seq_len=32, global_batch=4, vocab_size=100, seed=7)
    a = next(TokenStream(d))
    b = next(TokenStream(d))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_targets_are_next_tokens():
    batch = next(TokenStream(DataConfig(seq_len=16, global_batch=2)))
    np.testing.assert_array_equal(batch["tokens"][:, 1:],
                                  batch["targets"][:, :-1])


def test_process_shards_are_disjoint_slices():
    d = dict(seq_len=8, global_batch=4, vocab_size=100, seed=3)
    full = next(TokenStream(DataConfig(**d)))
    p0 = next(TokenStream(DataConfig(**d, process_index=0, process_count=2)))
    p1 = next(TokenStream(DataConfig(**d, process_index=1, process_count=2)))
    np.testing.assert_array_equal(full["tokens"][:2], p0["tokens"])
    np.testing.assert_array_equal(full["tokens"][2:], p1["tokens"])


def test_vocab_bound():
    batch = next(TokenStream(DataConfig(seq_len=64, global_batch=4,
                                        vocab_size=50)))
    assert batch["tokens"].max() < 50 and batch["tokens"].min() >= 0


def test_file_backed_corpus(tmp_path):
    toks = np.arange(10_000) % 251
    path = tmp_path / "corpus.bin"
    write_token_file(path, toks)
    d = DataConfig(seq_len=16, global_batch=2, vocab_size=251,
                   path=str(path))
    batch = next(TokenStream(d))
    np.testing.assert_array_equal(batch["tokens"][0],
                                  (np.arange(16) % 251).astype(np.int32))


def test_extra_modality_features():
    stream = TokenStream(
        DataConfig(seq_len=8, global_batch=2, vocab_size=50),
        extra_features={"image_embeds": ((4, 16), np.float32)})
    batch = next(stream)
    assert batch["image_embeds"].shape == (2, 4, 16)
    assert batch["image_embeds"].dtype == np.float32
