"""Continuous-batching scheduler: cross-task shared-encoder batches,
solo-vs-batched output equivalence, backpressure/admission control,
real queue-depth-aware routing, engine route/report consistency, and
the paged-KV decode substrate (generative heads shared across tasks
decode in one batched launch, token-exact vs solo submit())."""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.s2m3_zoo import get_clip_config
from repro.core.cluster import ClusterSpec, DeviceSpec
from repro.core.module import ModelSpec, ModuleSpec
from repro.core.placement import Placement
from repro.models import clip as C
from repro.s2m3 import Deployment, Request
from repro.serving.engine import S2M3Engine
from repro.serving.scheduler import (
    QueueFull, SchedulerConfig, ServeScheduler,
)

GB = 1024**3


@pytest.fixture(scope="module")
def zoo_slice():
    """Three tasks sharing encoders: retrieval + classification + VQA
    (the paper's multi-task zoo in miniature)."""
    ccfg = get_clip_config("mini-clip")
    params = C.init_clip(jax.random.PRNGKey(0), ccfg)
    vis = ModuleSpec("mini-vit", "encoder", "vision", 60_000,
                     flops_per_query=2e6)
    txt = ModuleSpec("mini-trf", "encoder", "text", 50_000,
                     flops_per_query=1e6)
    cos = ModuleSpec("cosine", "head", "task", 0)
    cls = ModuleSpec("mini-cls", "head", "task", 1_000, flops_per_query=1e4)
    lm = ModuleSpec("mini-lm", "head", "task", 80_000, flops_per_query=4e6)
    w_lm = jax.random.normal(jax.random.PRNGKey(6),
                             (2 * ccfg.embed_dim, 32)) * 0.3

    def lm_apply(p, enc):
        return jnp.concatenate([enc["vision"], enc["text"]], -1) @ p

    models = {
        "retrieval": ModelSpec("retrieval", "retrieval", (vis, txt), cos),
        "classify": ModelSpec("classify", "classification", (vis,), cls),
        "vqa": ModelSpec("vqa", "vqa-dec", (vis, txt), lm),
    }
    builders = {
        "mini-vit": lambda: (partial(C.encode_image, cfg=ccfg),
                             params["vision"]),
        "mini-trf": lambda: (partial(C.encode_text, cfg=ccfg),
                             params["text"]),
        "cosine": lambda: (
            lambda p, enc: C.retrieval_logits(enc["vision"], enc["text"], p),
            params["logit_scale"]),
        "mini-cls": lambda: (lambda p, enc: enc["vision"] @ p,
                             jnp.ones((ccfg.embed_dim, 7))),
        "mini-lm": lambda: (lm_apply, w_lm),
    }
    patches = jax.random.normal(jax.random.PRNGKey(1),
                                (2, ccfg.n_image_tokens, ccfg.vision_width))
    ids = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                             ccfg.vocab_size)
    return dict(models=models, builders=builders,
                inputs={"vision": patches, "text": ids})


def _cluster(n=4):
    return ClusterSpec(devices=[
        DeviceSpec(f"dev{i}", 1 * GB, (2.0 if i < 2 else 1.0) * 1e9)
        for i in range(n)
    ])


def _deploy(zoo_slice, **plan_kw):
    dep = Deployment(_cluster())
    for m in zoo_slice["models"].values():
        dep.add_model(m, zoo_slice["builders"])
    dep.plan(plan_kw.pop("placement", "greedy"),
             routing=plan_kw.pop("routing", "queue_aware"), **plan_kw)
    return dep.materialize()


def _workload(zoo_slice, n_each=1):
    reqs, rid = [], 0
    for _ in range(n_each):
        for name in ("retrieval", "classify", "vqa"):
            inp = dict(zoo_slice["inputs"])
            if name == "classify":
                inp = {"vision": inp["vision"]}
            reqs.append(Request(rid, name, "dev0", inputs=inp))
            rid += 1
    return reqs


# ---- acceptance: cross-task batches, solo == batched --------------------

def test_serve_forms_cross_task_batches_and_matches_solo(zoo_slice):
    dep = _deploy(zoo_slice)
    workload = _workload(zoo_slice, n_each=2)
    solo = [dep.submit(q) for q in workload]

    results = dep.serve(workload, max_batch=8)
    stats = dep.scheduler.stats_dict()
    # the shared vision encoder served >= 2 different tasks in one batch
    assert stats["mini-vit"]["cross_task_batches"] >= 1
    assert stats["mini-vit"]["max_batch"] >= 2
    assert dep.scheduler.cross_task_batches >= 1
    # batching is lossless: every request's output == its solo submit()
    for q, r, s in zip(workload, results, solo):
        assert r.rid == q.rid and r.model == q.model
        np.testing.assert_allclose(np.asarray(r.output),
                                   np.asarray(s.output), rtol=1e-5,
                                   atol=1e-6)


def test_serve_results_in_workload_order(zoo_slice):
    dep = _deploy(zoo_slice)
    workload = list(reversed(_workload(zoo_slice, n_each=1)))
    results = dep.serve(workload)
    assert [r.rid for r in results] == [q.rid for q in workload]
    for r in results:
        assert r.latency_s > 0
        assert r.devices          # routed hosts recorded per module


def test_serve_batches_within_max_batch(zoo_slice):
    dep = _deploy(zoo_slice)
    dep.serve(_workload(zoo_slice, n_each=4), max_batch=3)
    for st in dep.scheduler.stats_dict().values():
        assert st["max_batch"] <= 3


def test_serve_head_only_model(zoo_slice):
    """Head-only models (no encoders) flow through the head queue."""
    dep = _deploy(zoo_slice)
    dep.add_model(ModelSpec(
        "echo", "text-gen", (),
        ModuleSpec("echo-head", "head", "task", 10)),
        {"echo-head": lambda: (lambda p, enc: p, jnp.ones((3,)))})
    [res] = dep.serve([Request(0, "echo", "dev0")])
    np.testing.assert_array_equal(np.asarray(res.output), np.ones((3,)))


# ---- admission control / backpressure -----------------------------------

def test_backpressure_bounds_queue_depth(zoo_slice):
    dep = _deploy(zoo_slice)
    dep.serve(_workload(zoo_slice, n_each=6), max_batch=2,
              max_queue_depth=3)
    stats = dep.scheduler.stats_dict()
    # admission control bounds the queues requests are admitted into
    # (encoder stages; head stages are generated internally)
    for name in ("mini-vit", "mini-trf"):
        assert stats[name]["max_depth"] <= 3


def test_reject_admission_raises_queue_full(zoo_slice):
    dep = _deploy(zoo_slice)
    eng = dep.engine
    sched = ServeScheduler(eng, config=SchedulerConfig(
        max_batch=2, max_queue_depth=2, admission="reject"))
    reqs = _workload(zoo_slice, n_each=3)
    with pytest.raises(QueueFull, match="max_queue_depth"):
        for q in reqs:
            sched.submit(q)
    # the scheduler still drains what was admitted
    sched.drain()
    assert sched.results


def test_bad_scheduler_config_rejected():
    with pytest.raises(ValueError):
        SchedulerConfig(max_batch=0)
    with pytest.raises(ValueError):
        SchedulerConfig(admission="drop")


def test_bad_decode_config_rejected():
    for kw in ({"decode_rows": 0}, {"page_size": 0}, {"max_seq_len": 0}):
        with pytest.raises(ValueError):
            SchedulerConfig(**kw)
    # pool must hold at least one sequence's worth of pages + the dummy
    with pytest.raises(ValueError, match="decode_pages"):
        SchedulerConfig(page_size=8, max_seq_len=64, decode_pages=8)
    SchedulerConfig(page_size=8, max_seq_len=64, decode_pages=9)


def test_serve_requires_inputs(zoo_slice):
    dep = _deploy(zoo_slice)
    with pytest.raises(ValueError, match="no inputs"):
        dep.serve([Request(0, "retrieval", "dev0")])


# ---- real queue-aware routing -------------------------------------------

def test_queue_aware_spreads_replicated_module_across_hosts(zoo_slice):
    """With a replicated encoder, live occupancy must push consecutive
    batches onto different hosts.  The cluster is compute-dominated
    (free links, slow devices) so queueing — not comm — decides."""
    cluster = ClusterSpec(
        devices=[DeviceSpec(f"dev{i}", 1 * GB, 2e3) for i in range(2)],
        default_bandwidth=1e12, default_latency=0.0)
    dep = Deployment(cluster)
    for m in zoo_slice["models"].values():
        dep.add_model(m, zoo_slice["builders"])
    dep.plan("greedy", routing="queue_aware", replicate=True).materialize()
    hosts = dep.placement.devices_for("mini-vit")
    if len(hosts) < 2:
        pytest.skip("placement did not replicate mini-vit")
    sched = ServeScheduler(dep.engine,
                           config=SchedulerConfig(max_batch=1))
    for q in _workload(zoo_slice, n_each=2):
        sched.submit(q)
    sched.drain()
    used = {res.devices["mini-vit"] for res in sched.results.values()
            if "mini-vit" in res.devices}
    assert len(used) >= 2, f"queue-aware routing never spread load: {used}"


def test_scheduler_snapshot_feeds_engine_probe(zoo_slice):
    dep = _deploy(zoo_slice)
    sched = ServeScheduler(dep.engine)
    assert dep.engine.queue_probe is not None
    for q in _workload(zoo_slice, n_each=1):
        sched.submit(q)
    snap = dep.engine.queue_probe()
    assert snap.depth_of("mini-vit") >= 2      # retrieval + classify + vqa
    sched.drain()
    snap = sched.snapshot()
    assert snap.depth_of("mini-vit") == 0
    assert snap.free_map()                     # occupancy was charged


# ---- paged-KV decode substrate (acceptance) ------------------------------

@pytest.fixture(scope="module")
def shared_lm_deployment():
    """Two generative tasks ("chat" + "summarize") sharing one decoder
    module — the S2M3 split-and-share argument applied to a generative
    head on the paged decode substrate."""
    from repro.common.config import get_config
    from repro.models.api import build_model

    cfg = get_config("tinyllama-1.1b", smoke=True)
    bundle = build_model(cfg, compute_dtype=jnp.float32)
    params = bundle.init(jax.random.PRNGKey(0))
    head = ModuleSpec("tinylm", "head", "task", 100_000, generative=True,
                      kv_bytes_per_token=1024)
    builders = {"tinylm": lambda: (bundle, params)}
    dep = (Deployment(_cluster(2))
           .add_model(ModelSpec("chat", "chat", (), head), builders)
           .add_model(ModelSpec("summarize", "summarization", (), head))
           .plan("greedy").materialize())
    return dep


def _gen_workload(n=5):
    return [Request(rid=i, model=("chat" if i % 2 == 0 else "summarize"),
                    source="dev0", prompt=tuple(range(1, 3 + i)),
                    max_new_tokens=5 + i % 3)
            for i in range(n)]


def test_two_tasks_share_one_paged_decode_batch(shared_lm_deployment):
    """Acceptance: both tasks' decode streams ride one batched paged
    decode launch, and every request's tokens == its solo submit()."""
    dep = shared_lm_deployment
    reqs = _gen_workload(5)
    finish_order = []
    results = dep.serve(reqs, decode_rows=3, decode_pages=32, page_size=8,
                        max_seq_len=64,
                        on_finish=lambda r: finish_order.append(r.rid))
    # chat and summarize decoded together in >= 1 batched launch
    assert dep.scheduler.cross_task_decode_batches >= 1
    st = dep.scheduler.stats_dict()["tinylm"]
    assert st["cross_task_decode_batches"] >= 1
    assert st["decode_tokens"] == sum(max(q.max_new_tokens, 1) - 1
                                      for q in reqs)
    # batching is lossless: token-exact vs the solo generate() path
    for q, r in zip(reqs, results):
        solo = dep.submit(q)
        assert r.rid == q.rid and r.model == q.model
        assert list(r.output) == list(solo.output), q.rid
        assert any(stage == "decode" for _, stage, _, _ in r.timeline)
    # streaming callback saw every request exactly once
    assert sorted(finish_order) == [q.rid for q in reqs]
    # drained: rows free, only the dummy page left
    assert st["live_rows"] == 0 and st["waiting"] == 0
    assert st["pages_live"] == 1
    assert st["pages_peak"] > 1


def test_generative_requests_validated_at_submit(shared_lm_deployment):
    dep = shared_lm_deployment
    sched = ServeScheduler(dep.engine, config=SchedulerConfig(
        decode_rows=2, decode_pages=17, page_size=8, max_seq_len=32))
    with pytest.raises(ValueError, match="no prompt"):
        sched.submit(Request(0, "chat", "dev0"))
    with pytest.raises(ValueError, match="max_seq_len"):
        sched.submit(Request(1, "chat", "dev0", prompt=(1, 2, 3),
                             max_new_tokens=64))


# ---- engine route/report consistency (bugfix) ---------------------------

def test_unmapped_placement_host_raises():
    """A placement whose hosts are absent from device_map used to run on
    an arbitrary device while reporting the unmapped host; now it
    raises instead of letting real and reported routes diverge."""
    spec = ModuleSpec("h", "head", "task", 10)
    model = ModelSpec("m", "t", (), spec)
    eng = S2M3Engine({"dev0": jax.devices()[0]})
    eng.placement = Placement(assignment={"h": ["ghost-dev"]})
    with pytest.raises(KeyError, match="ghost-dev"):
        eng.deploy_model(model, {"h": lambda: (lambda p, enc: p,
                                               jnp.ones(2))})


# ---- runtime invariants + evict-during-serve (bugfixes) -----------------

def _gen_sched(dep, **kw):
    cfg = SchedulerConfig(decode_rows=2, decode_pages=17, page_size=8,
                          max_seq_len=32, **kw)
    return ServeScheduler(dep.engine, config=cfg)


def test_evict_during_serve_raises_structured_plan_error(
        shared_lm_deployment):
    """dep.evict() with requests in flight must raise a structured
    PlanError (not deregister a model out from under its sequences);
    after draining, the evict succeeds and verify() stays clean."""
    from repro.analysis.diagnostics import PlanError, errors

    dep = shared_lm_deployment
    head = dep.registry.models["chat"].head
    sched = _gen_sched(dep)
    old_sched, dep.scheduler = dep.scheduler, sched
    try:
        sched.submit(Request(0, "chat", "dev0", prompt=(1, 2, 3),
                             max_new_tokens=3))
        assert sched.inflight_models() == {"chat"}
        with pytest.raises(PlanError) as ei:
            dep.evict("chat")
        assert ei.value.diagnostics
        assert any("refcount-consistent" in d.code
                   for d in ei.value.diagnostics)
        # nothing was corrupted by the refused evict: model still
        # registered, shared decoder still referenced by both models,
        # runtime invariants hold
        assert "chat" in dep.registry.models
        assert dep.registry.refcount("tinylm") == 2
        assert sched.check_invariants() == []

        sched.drain()
        assert sched.inflight_models() == set()
        dep.evict("chat")                     # quiesced: now legal
        assert "chat" not in dep.registry.models
        assert dep.registry.refcount("tinylm") == 1  # summarize remains
        assert not errors(dep.verify())
    finally:
        dep.scheduler = old_sched
        if "chat" not in dep.registry.models:
            from repro.core.module import ModelSpec as _MS
            dep.add_model(_MS("chat", "chat", (), head))


def test_drain_asserts_runtime_invariant_catalog(shared_lm_deployment):
    """cfg.debug_invariants (default on) evaluates the shared invariant
    catalog after every step; a clean drain ends with page/row/refcount
    accounting the catalog accepts."""
    dep = shared_lm_deployment
    sched = _gen_sched(dep)
    assert sched.cfg.debug_invariants
    for i in range(3):
        sched.submit(Request(i, "chat" if i % 2 else "summarize", "dev0",
                             prompt=(1, 2, 3), max_new_tokens=2 + i))
    results = sched.drain()
    assert len(results) == 3
    assert sched.check_invariants() == []
    view = sched.decode["tinylm"].state_view()
    assert view.terminal and view.pages_total - view.pages_free == 1


def test_prefill_failure_does_not_leak_pages_or_rows(
        shared_lm_deployment, monkeypatch):
    """A prefill that raises used to strand the admitted row, its
    prefix pages, and the worst-case reservation (the model checker's
    pages/no-leak counterexample, hit at runtime via any device error
    during prefill).  The stream must roll the admission back."""
    from repro.analysis.invariants import check_state

    dep = shared_lm_deployment
    sched = _gen_sched(dep)
    sched.submit(Request(0, "chat", "dev0", prompt=(1, 2, 3),
                         max_new_tokens=2))
    stream = sched.decode["tinylm"]

    def boom(seq):
        raise RuntimeError("injected prefill failure")

    monkeypatch.setattr(stream, "_prefill", boom)
    with pytest.raises(RuntimeError, match="injected"):
        stream.tick()
    assert stream.rows.n_live == 0
    assert stream.pool.n_live_pages == 1       # dummy page only
    assert stream._reserved == 0 and stream._worst == {}
    view = stream.state_view()
    assert view.terminal
    assert check_state(view, where="runtime") == []


def test_tick_reports_per_tick_prefills(shared_lm_deployment):
    """TickReport.prefills used to echo the *cumulative* prefill
    counter; it must count this tick's admissions only."""
    dep = shared_lm_deployment
    sched = _gen_sched(dep)
    for i in range(2):
        sched.submit(Request(i, "chat", "dev0", prompt=(1, 2, 3),
                             max_new_tokens=4))
    stream = sched.decode["tinylm"]
    r1 = stream.tick()
    assert r1.prefills == 2
    r2 = stream.tick()
    assert r2.prefills == 0                    # not the cumulative 2
    sched.drain()
