"""Routing: Eq. (1)-(3) semantics, parallel speedup, pipelining, batching."""

import math

import pytest

from repro.core.cluster import ClusterSpec, DeviceSpec
from repro.core.module import ModelSpec, ModuleSpec
from repro.core.placement import Placement, greedy_place
from repro.core.routing import (
    Request, batch_factor, coalesce_batches, simulate, timeline_ascii,
)


def _two_encoder_setup(t_v=2.0, t_t=1.0, t_h=0.1):
    vis = ModuleSpec("vis", "encoder", "vision", 10, input_bytes=0,
                     output_bytes=0)
    txt = ModuleSpec("txt", "encoder", "text", 10, input_bytes=0,
                     output_bytes=0)
    head = ModuleSpec("head", "head", "task", 0, input_bytes=0)
    m = ModelSpec("m", "t", (vis, txt), head)
    cluster = ClusterSpec(
        devices=[DeviceSpec("a", 100, 1e9), DeviceSpec("b", 100, 1e9)],
        default_bandwidth=1e12, default_latency=0.0,
        comp_table={
            ("vis", "a"): t_v, ("vis", "b"): t_v * 2,
            ("txt", "a"): t_t, ("txt", "b"): t_t,
            ("head", "a"): t_h, ("head", "b"): t_h,
        })
    return m, cluster


def test_parallel_latency_is_max_not_sum():
    m, cluster = _two_encoder_setup()
    pl = Placement(assignment={"vis": ["a"], "txt": ["b"], "head": ["a"]})
    res = simulate([Request(0, "m", "a")], pl, cluster, [m])
    # Eq (1): max(2.0, 1.0) + 0.1, not 3.1
    assert math.isclose(res.latencies[0], 2.1, rel_tol=1e-6)


def test_colocated_encoders_serialize():
    m, cluster = _two_encoder_setup()
    pl = Placement(assignment={"vis": ["a"], "txt": ["a"], "head": ["a"]})
    res = simulate([Request(0, "m", "a")], pl, cluster, [m])
    assert math.isclose(res.latencies[0], 3.1, rel_tol=1e-6)


def test_routing_picks_min_comp_device():
    m, cluster = _two_encoder_setup()
    pl = Placement(assignment={"vis": ["a", "b"], "txt": ["b"], "head": ["a"]})
    res = simulate([Request(0, "m", "a")], pl, cluster, [m])
    comp_events = [e for e in res.events if e.kind == "comp" and e.module == "vis"]
    assert comp_events[0].device == "a"     # Eq. 7: t_comp 2.0 < 4.0


def test_pipelining_overlaps_requests():
    """Pipelining shrinks the MAKESPAN: request i+1's encoders start as
    soon as the modules free up, instead of waiting for request i's head."""
    vis = ModuleSpec("vis", "encoder", "vision", 10, input_bytes=0,
                     output_bytes=0)
    txt = ModuleSpec("txt", "encoder", "text", 10, input_bytes=0,
                     output_bytes=0)
    head = ModuleSpec("head", "head", "task", 0, input_bytes=0)
    m = ModelSpec("m", "t", (vis, txt), head)
    cluster = ClusterSpec(
        devices=[DeviceSpec(n, 100, 1e9) for n in "abc"],
        default_bandwidth=1e12, default_latency=0.0,
        comp_table={("vis", "a"): 2.0, ("vis", "b"): 9.0, ("vis", "c"): 9.0,
                    ("txt", "b"): 1.0, ("txt", "a"): 9.0, ("txt", "c"): 9.0,
                    ("head", "c"): 1.0, ("head", "a"): 9.0, ("head", "b"): 9.0})
    pl = Placement(assignment={"vis": ["a"], "txt": ["b"], "head": ["c"]})
    reqs = [Request(i, "m", "a") for i in range(3)]

    def makespan(res):
        return max(e.end for e in res.events)

    piped = simulate(reqs, pl, cluster, [m], pipeline=True)
    serial = simulate(reqs, pl, cluster, [m], pipeline=False)
    # serial: 3 x (2.0 + 1.0) = 9.0;  pipelined: 2+2+2+1 = 7.0
    assert makespan(piped) < makespan(serial) - 1.0


def test_comm_latency_charged():
    vis = ModuleSpec("vis", "encoder", "vision", 10,
                     input_bytes=10_000_000, output_bytes=0)
    head = ModuleSpec("head", "head", "task", 0, input_bytes=0)
    m = ModelSpec("m", "t", (vis,), head)
    cluster = ClusterSpec(
        devices=[DeviceSpec("src", 100, 1e9), DeviceSpec("dst", 100, 1e9)],
        default_bandwidth=10e6, default_latency=0.01,
        comp_table={("vis", "dst"): 1.0, ("vis", "src"): 50.0,
                    ("head", "dst"): 0.0, ("head", "src"): 0.0})
    pl = Placement(assignment={"vis": ["dst"], "head": ["dst"]})
    res = simulate([Request(0, "m", "src")], pl, cluster, [m])
    # 0.01 + 10MB/10MBps = 1.01 comm + 1.0 comp
    assert math.isclose(res.latencies[0], 2.01, rel_tol=1e-3)


def test_queue_aware_policy_beats_paper_under_congestion():
    """Beyond-paper routing: with replicas, queue-aware spreads load."""
    vis = ModuleSpec("vis", "encoder", "vision", 10, input_bytes=0,
                     output_bytes=0)
    head = ModuleSpec("head", "head", "task", 0, input_bytes=0)
    m = ModelSpec("m", "t", (vis,), head)
    cluster = ClusterSpec(
        devices=[DeviceSpec("fast", 100, 1e9), DeviceSpec("slow", 100, 1e9)],
        default_bandwidth=1e12, default_latency=0.0,
        comp_table={("vis", "fast"): 1.0, ("vis", "slow"): 1.2,
                    ("head", "fast"): 0.0, ("head", "slow"): 0.0})
    pl = Placement(assignment={"vis": ["fast", "slow"], "head": ["fast"]})
    reqs = [Request(i, "m", "fast") for i in range(4)]
    t_paper = simulate(reqs, pl, cluster, [m], policy="paper").total_latency
    t_qa = simulate(reqs, pl, cluster, [m], policy="queue_aware").total_latency
    assert t_qa < t_paper


def test_batching_factor_matches_paper_fit():
    # footnote 4: batch 1/10/20 -> 1.28/4.90/9.16 s  => ratios 1/3.83/7.16
    assert math.isclose(batch_factor(1), 1.0)
    assert math.isclose(batch_factor(10), 3.84, rel_tol=0.02)
    assert math.isclose(batch_factor(20), 7.0, rel_tol=0.05)


def test_coalesce_batches_merges_within_window():
    reqs = [Request(i, "m", "a", arrival=0.01 * i) for i in range(5)]
    merged = coalesce_batches(reqs, window=1.0)
    assert len(merged) == 1 and merged[0].batch == 5
    separate = coalesce_batches(reqs, window=0.0)
    assert len(separate) == 5


def test_coalesce_batches_preserves_work():
    """Regression: merging used to rebuild the Request without ``work``,
    silently dropping the retrieval text-encoder 100x multiplicity."""
    reqs = [Request(i, "m", "a", arrival=0.01 * i,
                    work=(("text", 100.0),)) for i in range(3)]
    merged = coalesce_batches(reqs, window=1.0)
    assert len(merged) == 1 and merged[0].batch == 3
    assert merged[0].work_of("text") == 100.0
    # worst-case per-modality multiplicity wins across merged requests
    mixed = coalesce_batches(
        [Request(0, "m", "a", work=(("text", 10.0),)),
         Request(1, "m", "a", arrival=0.01,
                 work=(("text", 100.0), ("vision", 2.0)))],
        window=1.0)
    assert mixed[0].work_of("text") == 100.0
    assert mixed[0].work_of("vision") == 2.0


def test_timeline_renders():
    m, cluster = _two_encoder_setup()
    pl = greedy_place([m], cluster)
    res = simulate([Request(0, "m", "a")], pl, cluster, [m])
    art = timeline_ascii(res)
    assert "vis" in art and "#" in art
