"""Routing: Eq. (1)-(3) semantics, parallel speedup, pipelining, batching."""

import math

import pytest

from repro.core.cluster import ClusterSpec, DeviceSpec
from repro.core.module import ModelSpec, ModuleSpec
from repro.core.placement import Placement, greedy_place
from repro.core.routing import (
    Request, SimResult, batch_factor, coalesce_batches, simulate,
    timeline_ascii,
)


def _two_encoder_setup(t_v=2.0, t_t=1.0, t_h=0.1):
    vis = ModuleSpec("vis", "encoder", "vision", 10, input_bytes=0,
                     output_bytes=0)
    txt = ModuleSpec("txt", "encoder", "text", 10, input_bytes=0,
                     output_bytes=0)
    head = ModuleSpec("head", "head", "task", 0, input_bytes=0)
    m = ModelSpec("m", "t", (vis, txt), head)
    cluster = ClusterSpec(
        devices=[DeviceSpec("a", 100, 1e9), DeviceSpec("b", 100, 1e9)],
        default_bandwidth=1e12, default_latency=0.0,
        comp_table={
            ("vis", "a"): t_v, ("vis", "b"): t_v * 2,
            ("txt", "a"): t_t, ("txt", "b"): t_t,
            ("head", "a"): t_h, ("head", "b"): t_h,
        })
    return m, cluster


def test_parallel_latency_is_max_not_sum():
    m, cluster = _two_encoder_setup()
    pl = Placement(assignment={"vis": ["a"], "txt": ["b"], "head": ["a"]})
    res = simulate([Request(0, "m", "a")], pl, cluster, [m])
    # Eq (1): max(2.0, 1.0) + 0.1, not 3.1
    assert math.isclose(res.latencies[0], 2.1, rel_tol=1e-6)


def test_colocated_encoders_serialize():
    m, cluster = _two_encoder_setup()
    pl = Placement(assignment={"vis": ["a"], "txt": ["a"], "head": ["a"]})
    res = simulate([Request(0, "m", "a")], pl, cluster, [m])
    assert math.isclose(res.latencies[0], 3.1, rel_tol=1e-6)


def test_routing_picks_min_comp_device():
    m, cluster = _two_encoder_setup()
    pl = Placement(assignment={"vis": ["a", "b"], "txt": ["b"], "head": ["a"]})
    res = simulate([Request(0, "m", "a")], pl, cluster, [m])
    comp_events = [e for e in res.events if e.kind == "comp" and e.module == "vis"]
    assert comp_events[0].device == "a"     # Eq. 7: t_comp 2.0 < 4.0


def test_pipelining_overlaps_requests():
    """Pipelining shrinks the MAKESPAN: request i+1's encoders start as
    soon as the modules free up, instead of waiting for request i's head."""
    vis = ModuleSpec("vis", "encoder", "vision", 10, input_bytes=0,
                     output_bytes=0)
    txt = ModuleSpec("txt", "encoder", "text", 10, input_bytes=0,
                     output_bytes=0)
    head = ModuleSpec("head", "head", "task", 0, input_bytes=0)
    m = ModelSpec("m", "t", (vis, txt), head)
    cluster = ClusterSpec(
        devices=[DeviceSpec(n, 100, 1e9) for n in "abc"],
        default_bandwidth=1e12, default_latency=0.0,
        comp_table={("vis", "a"): 2.0, ("vis", "b"): 9.0, ("vis", "c"): 9.0,
                    ("txt", "b"): 1.0, ("txt", "a"): 9.0, ("txt", "c"): 9.0,
                    ("head", "c"): 1.0, ("head", "a"): 9.0, ("head", "b"): 9.0})
    pl = Placement(assignment={"vis": ["a"], "txt": ["b"], "head": ["c"]})
    reqs = [Request(i, "m", "a") for i in range(3)]

    def makespan(res):
        return max(e.end for e in res.events)

    piped = simulate(reqs, pl, cluster, [m], pipeline=True)
    serial = simulate(reqs, pl, cluster, [m], pipeline=False)
    # serial: 3 x (2.0 + 1.0) = 9.0;  pipelined: 2+2+2+1 = 7.0
    assert makespan(piped) < makespan(serial) - 1.0


def test_comm_latency_charged():
    vis = ModuleSpec("vis", "encoder", "vision", 10,
                     input_bytes=10_000_000, output_bytes=0)
    head = ModuleSpec("head", "head", "task", 0, input_bytes=0)
    m = ModelSpec("m", "t", (vis,), head)
    cluster = ClusterSpec(
        devices=[DeviceSpec("src", 100, 1e9), DeviceSpec("dst", 100, 1e9)],
        default_bandwidth=10e6, default_latency=0.01,
        comp_table={("vis", "dst"): 1.0, ("vis", "src"): 50.0,
                    ("head", "dst"): 0.0, ("head", "src"): 0.0})
    pl = Placement(assignment={"vis": ["dst"], "head": ["dst"]})
    res = simulate([Request(0, "m", "src")], pl, cluster, [m])
    # 0.01 + 10MB/10MBps = 1.01 comm + 1.0 comp
    assert math.isclose(res.latencies[0], 2.01, rel_tol=1e-3)


def test_queue_aware_policy_beats_paper_under_congestion():
    """Beyond-paper routing: with replicas, queue-aware spreads load."""
    vis = ModuleSpec("vis", "encoder", "vision", 10, input_bytes=0,
                     output_bytes=0)
    head = ModuleSpec("head", "head", "task", 0, input_bytes=0)
    m = ModelSpec("m", "t", (vis,), head)
    cluster = ClusterSpec(
        devices=[DeviceSpec("fast", 100, 1e9), DeviceSpec("slow", 100, 1e9)],
        default_bandwidth=1e12, default_latency=0.0,
        comp_table={("vis", "fast"): 1.0, ("vis", "slow"): 1.2,
                    ("head", "fast"): 0.0, ("head", "slow"): 0.0})
    pl = Placement(assignment={"vis": ["fast", "slow"], "head": ["fast"]})
    reqs = [Request(i, "m", "fast") for i in range(4)]
    t_paper = simulate(reqs, pl, cluster, [m], policy="paper").total_latency
    t_qa = simulate(reqs, pl, cluster, [m], policy="queue_aware").total_latency
    assert t_qa < t_paper


def test_batching_factor_matches_paper_fit():
    # footnote 4: batch 1/10/20 -> 1.28/4.90/9.16 s  => ratios 1/3.83/7.16
    assert math.isclose(batch_factor(1), 1.0)
    assert math.isclose(batch_factor(10), 3.84, rel_tol=0.02)
    assert math.isclose(batch_factor(20), 7.0, rel_tol=0.05)


def test_coalesce_batches_merges_within_window():
    reqs = [Request(i, "m", "a", arrival=0.01 * i) for i in range(5)]
    merged = coalesce_batches(reqs, window=1.0)
    assert len(merged) == 1 and merged[0].batch == 5
    separate = coalesce_batches(reqs, window=0.0)
    assert len(separate) == 5


def test_coalesce_batches_preserves_work():
    """Regression: merging used to rebuild the Request without ``work``,
    silently dropping the retrieval text-encoder 100x multiplicity."""
    reqs = [Request(i, "m", "a", arrival=0.01 * i,
                    work=(("text", 100.0),)) for i in range(3)]
    merged = coalesce_batches(reqs, window=1.0)
    assert len(merged) == 1 and merged[0].batch == 3
    assert merged[0].work_of("text") == 100.0
    # worst-case per-modality multiplicity wins across merged requests
    mixed = coalesce_batches(
        [Request(0, "m", "a", work=(("text", 10.0),)),
         Request(1, "m", "a", arrival=0.01,
                 work=(("text", 100.0), ("vision", 2.0)))],
        window=1.0)
    assert mixed[0].work_of("text") == 100.0
    assert mixed[0].work_of("vision") == 2.0


def test_head_only_requests_contend_on_uplink():
    """Regression: head-only models shipped their raw input without
    serializing on the source uplink, so they got free bandwidth the
    encoder path pays for.  Two concurrent sends must queue."""
    head = ModuleSpec("head", "head", "task", 0, input_bytes=10_000_000)
    m = ModelSpec("m", "t", (), head)
    cluster = ClusterSpec(
        devices=[DeviceSpec("src", 100, 1e9), DeviceSpec("dst", 100, 1e9)],
        default_bandwidth=10e6, default_latency=0.0,
        comp_table={("head", "dst"): 0.0, ("head", "src"): 50.0})
    pl = Placement(assignment={"head": ["dst"]})
    res = simulate([Request(0, "m", "src"), Request(1, "m", "src")],
                   pl, cluster, [m])
    # each send takes 1.0 s on the shared uplink: r0 lands at 1.0,
    # r1's send starts only after r0's finishes -> latency 2.0
    assert math.isclose(res.latencies[0], 1.0, rel_tol=1e-6)
    assert math.isclose(res.latencies[1], 2.0, rel_tol=1e-6)
    sends = [e for e in res.events if e.kind == "comm_in"]
    assert len(sends) == 2 and sends[1].start >= sends[0].end


def test_head_only_send_mixes_with_encoder_sends():
    """The head-only send shares the uplink with encoder sends of other
    requests from the same source."""
    vis = ModuleSpec("vis", "encoder", "vision", 10,
                     input_bytes=10_000_000, output_bytes=0)
    enc_m = ModelSpec("em", "t", (vis,),
                      ModuleSpec("ehead", "head", "task", 0, input_bytes=0))
    ho_head = ModuleSpec("hhead", "head", "task", 0, input_bytes=10_000_000)
    ho_m = ModelSpec("hm", "t", (), ho_head)
    cluster = ClusterSpec(
        devices=[DeviceSpec("src", 100, 1e9), DeviceSpec("dst", 100, 1e9)],
        default_bandwidth=10e6, default_latency=0.0,
        comp_table={("vis", "dst"): 0.1, ("vis", "src"): 50.0,
                    ("ehead", "dst"): 0.0, ("ehead", "src"): 50.0,
                    ("hhead", "dst"): 0.0, ("hhead", "src"): 50.0})
    pl = Placement(assignment={"vis": ["dst"], "ehead": ["dst"],
                               "hhead": ["dst"]})
    res = simulate([Request(0, "em", "src"), Request(1, "hm", "src")],
                   pl, cluster, [enc_m, ho_m])
    # r1's raw-input send waits for r0's encoder send (1.0 s each)
    assert math.isclose(res.latencies[1], 2.0, rel_tol=1e-6)


def test_max_latency_zero_for_feasible_empty_workload():
    """Regression: a feasible empty SimResult reported max=inf, making
    PlanReport.summary() print a bogus number."""
    assert SimResult().max_latency == 0.0
    assert SimResult(feasible=False).max_latency == float("inf")
    m, cluster = _two_encoder_setup()
    pl = Placement(assignment={"vis": ["a"], "txt": ["b"], "head": ["a"]})
    res = simulate([], pl, cluster, [m])
    assert res.feasible and res.max_latency == 0.0


def test_coalesce_refuses_payload_carrying_requests():
    """Regression: merging kept only the first request's inputs/
    head_extra, so a coalesced Request fed to submit() silently dropped
    the other requests' payloads.  Payload requests never merge."""
    plain = [Request(i, "m", "a", arrival=0.01 * i) for i in range(2)]
    loaded = [Request(10 + i, "m", "a", arrival=0.01 * i,
                      inputs={"vision": [i]}) for i in range(2)]
    extra = Request(20, "m", "a", arrival=0.0, head_extra={"k": 1})
    merged = coalesce_batches(plain + loaded + [extra], window=1.0)
    # the two plain requests merged; the three payload ones survived
    assert len(merged) == 4
    assert sorted(q.rid for q in merged if q.batch == 1) == [10, 11, 20]
    [batched] = [q for q in merged if q.batch == 2]
    assert batched.inputs is None
    for q in merged:
        if q.rid == 10:
            assert q.inputs == {"vision": [0]}    # payload intact
        if q.rid == 20:
            assert q.head_extra == {"k": 1}


def test_timeline_renders():
    m, cluster = _two_encoder_setup()
    pl = greedy_place([m], cluster)
    res = simulate([Request(0, "m", "a")], pl, cluster, [m])
    art = timeline_ascii(res)
    assert "vis" in art and "#" in art
