"""Logical-axis sharding rules: resolution, demotion, hypothesis validity."""

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

try:                                  # property tests need hypothesis; the
    import hypothesis.strategies as st   # rest of the file runs without it
    from hypothesis import given, settings
except ModuleNotFoundError:           # pragma: no cover - minimal install
    st = None

from repro.common.sharding import (
    DEFAULT_RULES, local_mesh, merge_rules, spec_for, tree_pspecs,
)
from repro.layers.initializers import WSpec


def _mesh22():
    devs = jax.devices()
    if len(devs) >= 4:
        arr = np.asarray(devs[:4]).reshape(2, 2)
    else:
        arr = np.asarray([devs[0]] * 4).reshape(2, 2)  # abstract-only use
    return Mesh(arr, ("data", "model"))


# NOTE: spec resolution only reads mesh.shape, never devices, so a
# repeated-device mesh is fine for these tests.
MESH = _mesh22()
RULES = merge_rules(None)


def test_basic_resolution():
    assert spec_for((8, 16), ("embed", "mlp"), RULES, MESH) == P("data", "model")


def test_indivisible_dim_demoted():
    # dim 7 not divisible by data axis (2) -> replicated
    assert spec_for((7, 16), ("embed", "mlp"), RULES, MESH) == P(None, "model")


def test_axis_never_used_twice():
    spec = spec_for((8, 8), ("mlp", "heads"), RULES, MESH)  # both -> model
    used = [s for s in spec if s is not None]
    assert used.count("model") <= 1


def test_missing_pod_axis_dropped():
    # "batch" -> ("pod","data"); no pod axis in a 2D mesh
    assert spec_for((8,), ("batch",), RULES, MESH) == P("data")


def test_merge_rules_override():
    rules = merge_rules({"embed": None})
    assert spec_for((8, 16), ("embed", "mlp"), rules, MESH) == P(None, "model")
    # base table untouched
    assert DEFAULT_RULES["embed"] == ("pod", "data")


def test_tree_pspecs_over_wspec_tree():
    tree = {"w": WSpec((8, 16), ("embed", "mlp")),
            "b": WSpec((16,), ("norm",))}
    specs = tree_pspecs(tree, RULES, MESH)
    assert specs["w"] == P("data", "model")
    assert specs["b"] == P(None)


if st is not None:
    @settings(max_examples=80, deadline=None)
    @given(
        dims=st.lists(st.integers(1, 64), min_size=1, max_size=4),
        axes=st.lists(st.sampled_from(
            [None, "embed", "mlp", "heads", "batch", "vocab", "experts"]),
            min_size=1, max_size=4),
    )
    def test_spec_always_valid(dims, axes):
        n = min(len(dims), len(axes))
        dims, axes = dims[:n], axes[:n]
        spec = spec_for(dims, axes, RULES, MESH)
        used = []
        for dim, entry in zip(dims, spec):
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            prod = 1
            for a in names:
                assert a in MESH.shape
                assert a not in used
                used.append(a)
                prod *= MESH.shape[a]
            assert dim % prod == 0        # shardability invariant
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_spec_always_valid():
        pass
