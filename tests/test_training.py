"""Training substrate: loss goes down, microbatch equivalence, optimizer
semantics, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import TrainConfig, get_config
from repro.common.pytree import tree_allclose
from repro.models.api import build_model
from repro.training.data import DataConfig, TokenStream
from repro.training.optimizer import (
    adamw_update, compress_grads_int8, init_state, lr_schedule, state_specs,
)
from repro.training.train_step import make_train_step


def _setup(microbatches=1, **tkw):
    cfg = get_config("tinyllama-1.1b", smoke=True)
    bundle = build_model(cfg, compute_dtype=jnp.float32)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=60,
                       microbatches=microbatches, **tkw)
    params = bundle.init(jax.random.PRNGKey(0))
    state = init_state(params, tcfg)
    step = jax.jit(make_train_step(bundle, tcfg))
    return cfg, bundle, tcfg, state, step


def test_loss_decreases_on_synthetic_data():
    cfg, bundle, tcfg, state, step = _setup()
    data = TokenStream(DataConfig(seq_len=32, global_batch=8,
                                  vocab_size=cfg.vocab_size))
    losses = []
    for i, batch in zip(range(40), data):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])
    assert int(state["step"]) == 40


def test_microbatching_matches_full_batch_grads():
    cfg, bundle, tcfg1, state1, step1 = _setup(microbatches=1)
    _, _, tcfg2, state2, step2 = _setup(microbatches=2)
    data = TokenStream(DataConfig(seq_len=16, global_batch=4,
                                  vocab_size=cfg.vocab_size))
    batch = {k: jnp.asarray(v) for k, v in next(data).items()}
    s1, m1 = step1(state1, batch)
    s2, m2 = step2(state2, batch)
    # same params after one update (up to accumulation-order fp error)
    flat1 = jax.tree.leaves(s1["params"])
    flat2 = jax.tree.leaves(s2["params"])
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


def test_lr_schedule_warmup_and_decay():
    tcfg = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=100)
    lr5 = float(lr_schedule(tcfg, jnp.asarray(5)))
    lr10 = float(lr_schedule(tcfg, jnp.asarray(10)))
    lr100 = float(lr_schedule(tcfg, jnp.asarray(100)))
    assert lr5 < lr10
    assert lr100 < lr10
    assert lr100 >= 0.09          # cosine floor at 10%


def test_adamw_moves_params_against_gradient():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=10,
                       weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.ones((4, 4))}
    state = init_state(params, tcfg)
    grads = {"w": jnp.ones((4, 4))}
    new_state, metrics = adamw_update(state, grads, tcfg)
    assert float(new_state["params"]["w"].mean()) < 1.0
    assert float(metrics["grad_norm"]) > 0


def test_grad_clip_limits_update_norm():
    tcfg = TrainConfig(learning_rate=0.1, grad_clip=1.0, warmup_steps=0,
                       total_steps=10)
    params = {"w": jnp.zeros((8,))}
    state = init_state(params, tcfg)
    huge = {"w": jnp.full((8,), 1e6)}
    new_state, metrics = adamw_update(state, huge, tcfg)
    assert np.isfinite(np.asarray(new_state["params"]["w"])).all()


def test_int8_compression_preserves_grads_approximately():
    g = {"a": jax.random.normal(jax.random.PRNGKey(0), (128,)),
         "b": jax.random.normal(jax.random.PRNGKey(1), (64, 8)) * 10}
    gq = compress_grads_int8(g, jax.random.PRNGKey(2))
    for k in g:
        err = np.abs(np.asarray(gq[k]) - np.asarray(g[k])).max()
        scale = np.abs(np.asarray(g[k])).max() / 127.0
        assert err <= scale * 1.01   # one quantization step

    # stochastic rounding is unbiased: mean error ~ 0
    big = jax.random.normal(jax.random.PRNGKey(3), (100_000,))
    bq = compress_grads_int8({"x": big}, jax.random.PRNGKey(4))["x"]
    assert abs(float(jnp.mean(bq - big))) < 1e-4


def test_moment_dtype_bf16():
    tcfg = TrainConfig(moment_dtype="bfloat16")
    params = {"w": jnp.ones((4,))}
    state = init_state(params, tcfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    new_state, _ = adamw_update(state, {"w": jnp.ones((4,))}, tcfg)
    assert new_state["m"]["w"].dtype == jnp.bfloat16


def test_state_specs_mirror_param_tree():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    bundle = build_model(cfg)
    ss = state_specs(bundle.specs, TrainConfig())
    p_leaves = len(jax.tree.leaves(
        bundle.specs, is_leaf=lambda x: hasattr(x, "axes")))
    m_leaves = len(jax.tree.leaves(
        ss["m"], is_leaf=lambda x: hasattr(x, "axes")))
    assert p_leaves == m_leaves
