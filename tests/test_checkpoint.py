"""Checkpointing: roundtrip, atomic commit, latest-step discovery, GC."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as ckpt


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "step": jnp.asarray(7, jnp.int32),
        "params": {"w": jax.random.normal(k, (4, 3)),
                   "nested": {"b": jnp.arange(5, dtype=jnp.float32)}},
        "m": {"w": jnp.zeros((4, 3)),
              "nested": {"b": jnp.zeros((5,))}},
    }


def test_roundtrip(tmp_path):
    state = _state()
    ckpt.save(state, tmp_path, step=7)
    restored = ckpt.restore(state, tmp_path)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_requires_commit(tmp_path):
    state = _state()
    ckpt.save(state, tmp_path, step=3)
    ckpt.save(state, tmp_path, step=9)
    assert ckpt.latest_step(tmp_path) == 9
    # an uncommitted (crashed) save is invisible
    crashed = tmp_path / "step_00000012" / "proc0"
    crashed.mkdir(parents=True)
    assert ckpt.latest_step(tmp_path) == 9


def test_restore_validates_shapes(tmp_path):
    state = _state()
    ckpt.save(state, tmp_path, step=1)
    wrong = dict(state)
    wrong["params"] = {"w": jnp.zeros((9, 9)),
                       "nested": {"b": jnp.zeros((5,))}}
    with pytest.raises(ValueError):
        ckpt.restore(wrong, tmp_path)


def test_gc_keeps_latest_k(tmp_path):
    state = _state()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(state, tmp_path, step=s, keep=2)
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [4, 5]


def test_save_async_completes(tmp_path):
    state = _state()
    t = ckpt.save_async(state, tmp_path, step=11)
    t.join(timeout=30)
    assert ckpt.latest_step(tmp_path) == 11
    restored = ckpt.restore(state, tmp_path, step=11)
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(state["params"]["w"]))


def test_crash_restart_resumes_from_checkpoint(tmp_path):
    """The fault-tolerance contract: train, checkpoint, 'crash', restore,
    and the step counter + params continue from the committed state."""
    from repro.common.config import TrainConfig, get_config
    from repro.models.api import build_model
    from repro.training.data import DataConfig, TokenStream
    from repro.training.optimizer import init_state
    from repro.training.train_step import make_train_step

    cfg = get_config("tinyllama-1.1b", smoke=True)
    bundle = build_model(cfg, compute_dtype=jnp.float32)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=20)
    state = init_state(bundle.init(jax.random.PRNGKey(0)), tcfg)
    step = jax.jit(make_train_step(bundle, tcfg))
    data = TokenStream(DataConfig(seq_len=16, global_batch=4,
                                  vocab_size=cfg.vocab_size))
    for i, batch in zip(range(3), data):
        state, _ = step(state, {k: jnp.asarray(v) for k, v in batch.items()})
    ckpt.save(state, tmp_path, step=int(state["step"]))

    # "crash": rebuild everything from scratch, restore
    state2 = init_state(bundle.init(jax.random.PRNGKey(99)), tcfg)
    state2 = ckpt.restore(state2, tmp_path)
    assert int(state2["step"]) == 3
    for a, b in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(state2["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and it can keep stepping
    batch = {k: jnp.asarray(v) for k, v in next(data).items()}
    state2, metrics = step(state2, batch)
    assert np.isfinite(float(metrics["loss"]))
