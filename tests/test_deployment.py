"""s2m3.Deployment facade: plan/materialize/submit lifecycle, policy
registries, sim-vs-real route agreement, evict/redeploy refcounts,
elastic replan with live weight migration."""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.s2m3_zoo import get_clip_config
from repro.core.cluster import ClusterSpec, DeviceSpec
from repro.core.module import ModelSpec, ModuleSpec
from repro.models import clip as C
from repro.s2m3 import (
    Deployment, Request, available_placements, available_routings,
    get_placement, get_routing, register_placement,
)

GB = 1024**3


@pytest.fixture(scope="module")
def clip_setup():
    ccfg = get_clip_config("mini-clip")
    params = C.init_clip(jax.random.PRNGKey(0), ccfg)
    vis = ModuleSpec("mini-vit", "encoder", "vision", 60_000,
                     flops_per_query=2e6)
    txt = ModuleSpec("mini-trf", "encoder", "text", 50_000,
                     flops_per_query=1e6)
    cos = ModuleSpec("cosine", "head", "task", 0)
    cls = ModuleSpec("mini-cls", "head", "task", 1_000, flops_per_query=1e4)
    retrieval = ModelSpec("retrieval", "retrieval", (vis, txt), cos)
    classify = ModelSpec("classify", "classification", (vis,), cls)
    builders = {
        "mini-vit": lambda: (partial(C.encode_image, cfg=ccfg),
                             params["vision"]),
        "mini-trf": lambda: (partial(C.encode_text, cfg=ccfg),
                             params["text"]),
        "cosine": lambda: (
            lambda p, enc: C.retrieval_logits(enc["vision"], enc["text"], p),
            params["logit_scale"]),
        "mini-cls": lambda: (lambda p, enc: enc["vision"] @ p,
                             jnp.ones((ccfg.embed_dim, 7))),
    }
    patches = jax.random.normal(jax.random.PRNGKey(1),
                                (2, ccfg.n_image_tokens, ccfg.vision_width))
    ids = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                             ccfg.vocab_size)
    return dict(ccfg=ccfg, params=params, retrieval=retrieval,
                classify=classify, builders=builders,
                inputs={"vision": patches, "text": ids})


def _cluster(n=4):
    return ClusterSpec(devices=[
        DeviceSpec(f"dev{i}", 1 * GB, (2.0 if i < 2 else 1.0) * 1e9)
        for i in range(n)
    ])


def _fresh(clip_setup, *, materialize=True):
    dep = (Deployment(_cluster())
           .add_model(clip_setup["retrieval"], clip_setup["builders"])
           .add_model(clip_setup["classify"])
           .plan("greedy", routing="paper"))
    if materialize:
        dep.materialize()
    return dep


# ---- registries ---------------------------------------------------------

def test_builtin_policies_registered():
    assert {"greedy", "no_share", "centralized", "optimal"} <= \
        set(available_placements())
    assert {"paper", "queue_aware"} <= set(available_routings())


def test_unknown_policy_names_raise():
    with pytest.raises(KeyError, match="unknown placement"):
        get_placement("does-not-exist")
    with pytest.raises(KeyError, match="unknown routing"):
        get_routing("does-not-exist")
    with pytest.raises(KeyError):
        Deployment(_cluster()).plan("does-not-exist")
    with pytest.raises(KeyError):
        Deployment(_cluster()).plan("greedy", routing="does-not-exist")


def test_custom_placement_registers():
    @register_placement("everything-on-dev0")
    def _pin(models, cluster, *, workload=None, **_):
        from repro.core.placement import centralized_place

        return centralized_place(models, cluster, cluster.devices[0].name)

    m = ModelSpec("m", "t", (), ModuleSpec("h", "head", "task", 10))
    dep = Deployment(_cluster()).add_model(m).plan("everything-on-dev0")
    assert dep.placement.assignment["h"] == ["dev0"]


# ---- planning + report --------------------------------------------------

def test_plan_report_memory_ledger(clip_setup):
    dep = _fresh(clip_setup, materialize=False)
    report = dep.report()
    assert report.feasible
    total_used = sum(r["used"] for r in report.memory.values())
    assert total_used == report.shared_bytes > 0
    for dev, row in report.memory.items():
        assert 0 <= row["used"] <= row["capacity"]
    assert report.sharing_savings > 0       # mini-vit shared by both tasks


def test_simulate_without_materialize(clip_setup):
    dep = _fresh(clip_setup, materialize=False)
    rep = dep.simulate([Request(0, "retrieval", "dev0"),
                        Request(1, "classify", "dev0", arrival=0.1)])
    assert rep.sim is not None and rep.feasible
    assert rep.mean_latency > 0
    assert set(rep.routes) == {0, 1}
    # every routed module landed on a device from its placement
    for rid, route in rep.routes.items():
        for mod, dev in route.items():
            assert dev in rep.assignments[mod]


# ---- acceptance: one Request, predicted AND real ------------------------

def test_same_request_drives_sim_and_real(clip_setup):
    dep = _fresh(clip_setup)
    req = Request(7, "retrieval", "dev0", inputs=clip_setup["inputs"])
    predicted = dep.simulate([req])
    result = dep.submit(req)
    assert result.rid == 7
    assert result.devices == predicted.routes[7]   # module -> device match
    mono = C.clip_forward(clip_setup["params"],
                          clip_setup["inputs"]["vision"],
                          clip_setup["inputs"]["text"], clip_setup["ccfg"])
    np.testing.assert_array_equal(np.asarray(result.output),
                                  np.asarray(mono))


def test_submit_without_inputs_raises(clip_setup):
    dep = _fresh(clip_setup)
    with pytest.raises(ValueError, match="no inputs"):
        dep.submit(Request(0, "retrieval", "dev0"))


def test_infer_requires_materialize(clip_setup):
    dep = _fresh(clip_setup, materialize=False)
    with pytest.raises(RuntimeError, match="not materialized"):
        dep.infer("retrieval", clip_setup["inputs"])


# ---- lifecycle: deploy -> evict -> redeploy -----------------------------

def test_evict_keeps_shared_modules_alive(clip_setup):
    dep = _fresh(clip_setup)
    assert dep.registry.refcount("mini-vit") == 2
    freed = dep.evict("retrieval")
    # shared encoder survives while classify still references it
    assert "mini-vit" not in freed
    assert {"mini-trf", "cosine"} == set(freed)
    assert dep.registry.refcount("mini-vit") == 1
    assert "mini-vit" in dep.engine.runtimes
    assert "cosine" not in dep.engine.runtimes
    # classify still serves after the eviction
    res = dep.infer("classify", {"vision": clip_setup["inputs"]["vision"]})
    assert res.output.shape == (2, 7)
    # last reference: runtime freed at refcount 0
    freed = dep.evict("classify")
    assert "mini-vit" in freed
    assert dep.registry.refcount("mini-vit") == 0
    assert not dep.engine.runtimes


def test_redeploy_after_evict(clip_setup):
    dep = _fresh(clip_setup)
    dep.evict("retrieval")
    dep.evict("classify")
    # hot re-admission on the live deployment rebuilds the runtimes
    dep.add_model(clip_setup["retrieval"], clip_setup["builders"])
    req = Request(1, "retrieval", "dev0", inputs=clip_setup["inputs"])
    mono = C.clip_forward(clip_setup["params"],
                          clip_setup["inputs"]["vision"],
                          clip_setup["inputs"]["text"], clip_setup["ccfg"])
    np.testing.assert_array_equal(np.asarray(dep.submit(req).output),
                                  np.asarray(mono))


def test_hot_add_model_after_materialize(clip_setup):
    dep = (Deployment(_cluster())
           .add_model(clip_setup["retrieval"], clip_setup["builders"])
           .plan("greedy", routing="paper")
           .materialize())
    dep.add_model(clip_setup["classify"])      # builders already known
    assert "mini-cls" in dep.engine.runtimes
    assert dep.registry.refcount("mini-vit") == 2
    res = dep.infer("classify", {"vision": clip_setup["inputs"]["vision"]})
    assert res.output.shape == (2, 7)


def test_no_share_is_simulation_only(clip_setup):
    dep = (Deployment(_cluster())
           .add_model(clip_setup["retrieval"], clip_setup["builders"])
           .plan("no_share", routing="paper"))
    assert dep.simulate is not None          # planning/reporting still works
    assert dep.report().shared_bytes > 0
    with pytest.raises(NotImplementedError, match="simulation-only"):
        dep.materialize()
    live = _fresh(clip_setup)
    with pytest.raises(NotImplementedError, match="no_share"):
        live.plan("no_share")


# ---- elasticity ---------------------------------------------------------

def test_replan_migrates_live_weights(clip_setup):
    dep = _fresh(clip_setup)
    hosted_on = {name: rt.host for name, rt in dep.engine.runtimes.items()}
    gone = sorted({h for h in hosted_on.values()})[0]
    report = dep.replan(dep.cluster.without(gone))
    assert report.feasible
    for hosts in report.assignments.values():
        assert gone not in hosts
    for name, rt in dep.engine.runtimes.items():
        assert rt.host != gone
    # modules that left `gone` are listed as migrations
    migrated = {m for m, _ in report.migrations}
    assert {m for m, h in hosted_on.items() if h == gone} <= migrated
    # still serves, bit-identically
    req = Request(2, "retrieval", "dev1", inputs=clip_setup["inputs"])
    mono = C.clip_forward(clip_setup["params"],
                          clip_setup["inputs"]["vision"],
                          clip_setup["inputs"]["text"], clip_setup["ccfg"])
    np.testing.assert_array_equal(np.asarray(dep.submit(req).output),
                                  np.asarray(mono))


def test_replan_to_grown_cluster_extends_device_map(clip_setup):
    """A device joining the pool must be usable by migrations — the
    engine's device_map is extended, not silently skipped."""
    dep = _fresh(clip_setup)
    fast = DeviceSpec("dev-new", 1 * GB, 100e9)   # dominates every pick
    report = dep.replan(dep.cluster.with_device(fast))
    assert any(h == "dev-new" for hosts in report.assignments.values()
               for h in hosts)
    assert "dev-new" in dep.engine.device_map
    moved_to_new = {m for m, h in report.migrations if h == "dev-new"}
    assert moved_to_new
    for name in moved_to_new:
        if name in dep.engine.runtimes:
            assert dep.engine.runtimes[name].host == "dev-new"
    # sim and real still agree after the grow
    req = Request(3, "retrieval", "dev0", inputs=clip_setup["inputs"])
    assert dep.submit(req).devices == dep.simulate([req]).routes[3]
