"""Schedule-space model checker: exhaustive exploration of bounded
serving interleavings against the shared invariant catalog, replayable
counterexamples, the seeded-mutation self-test, and the
``Deployment.verify(model_check=True)`` wiring."""

import json

import pytest

from repro.analysis import invariants as inv
from repro.analysis import modelcheck as mc
from repro.analysis.diagnostics import Severity, errors
from repro.core.cluster import ClusterSpec, DeviceSpec
from repro.s2m3 import Deployment

pytestmark = pytest.mark.modelcheck

GB = 1024**3


# ---- invariant catalog --------------------------------------------------

def test_catalog_is_populated_and_layered():
    cat = inv.catalog()
    names = {i.name for i in cat}
    assert {"pages/no-double-free", "pages/conservation", "pages/no-leak",
            "admission/reservation-sound", "rows/slot-consistent",
            "registry/refcount-consistent", "registry/decoder-pinned",
            "sched/deadlock-free", "slo/bounded-inversion"} <= names
    # every invariant names at least one enforcement layer, and the
    # runtime subset the scheduler asserts is non-empty
    assert all(i.checked_by for i in cat)
    assert any("runtime" in i.checked_by for i in cat)
    assert any("model-check" in i.checked_by for i in cat)
    for name in names:
        assert name in inv.catalog_table()


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="registered twice"):
        inv.invariant("pages/no-leak", layer="pages")(lambda v: [])


def test_check_state_filters_by_layer():
    # a deadlocked non-terminal state: model-check-only invariant
    view = inv.StateView(enabled=(), terminal=False,
                         waiting=(inv.WaitView(rid=1, worst_pages=1),))
    hits = {n for n, _ in inv.check_state(view)}
    assert "sched/deadlock-free" in hits
    runtime_hits = {n for n, _ in inv.check_state(view, where="runtime")}
    assert "sched/deadlock-free" not in runtime_hits


def test_partial_view_is_silent():
    # producers that only know part of the state trigger nothing
    assert inv.check_state(inv.StateView()) == []


# ---- clean exploration --------------------------------------------------

def test_default_scenario_verifies_clean_and_complete():
    res = mc.check(mc.default_scenario())
    assert res.ok and res.complete
    assert res.counterexample is None
    assert res.states > 10 and res.transitions >= res.states - 1
    assert "no invariant violation" in res.summary()


def test_budget_truncates_exploration():
    res = mc.check(mc.default_scenario(), budget_s=0.0)
    assert not res.complete and res.counterexample is None


def test_config_validation():
    with pytest.raises(ValueError, match="unknown mutation"):
        mc.MCConfig(requests=(), models=(), mutate="no-such-bug")
    with pytest.raises(ValueError, match="unregistered"):
        mc.MCConfig(requests=(mc.MCRequest(rid=1, model="ghost"),),
                    models=(mc.MCModel("chat", decoder="lm"),))


# ---- seeded mutations ---------------------------------------------------

@pytest.mark.parametrize("mutation", sorted(mc.MUTATIONS))
def test_mutation_caught_and_replayable(mutation):
    """Each seeded serving bug is caught by the invariant it breaks, and
    the counterexample script replays to the same violation."""
    cfg = mc.default_scenario(mutate=mutation)
    res = mc.check(cfg)
    assert res.counterexample is not None, mutation
    cx = res.counterexample
    assert cx.invariant in mc.MUTATIONS[mutation]
    assert cx.script and cx.format_script()
    replayed = mc.replay(cfg, cx.script)
    assert any(name == cx.invariant for name, _ in replayed)


def test_self_test_is_all_clear():
    diags = mc.self_test()
    assert diags and not errors(diags)
    caught = {d.message.split("'")[1] for d in diags
              if d.code == "modelcheck/mutation-caught"}
    assert caught == set(mc.MUTATIONS)


def test_counterexample_exports_chrome_trace(tmp_path):
    res = mc.check(mc.default_scenario(mutate="double-free"))
    cx = res.counterexample
    trace = cx.to_chrome_trace()
    assert trace["traceEvents"]
    path = tmp_path / "cx.json"
    cx.save_trace(path)
    assert json.loads(path.read_text())["traceEvents"]


def test_replay_rejects_disabled_transition():
    cfg = mc.default_scenario()
    with pytest.raises(ValueError, match="not enabled"):
        mc.replay(cfg, [("finish", 99)])


# ---- deployment wiring --------------------------------------------------

def _dep():
    from repro.core.module import ModelSpec, ModuleSpec

    cluster = ClusterSpec(devices=[
        DeviceSpec(f"dev{i}", 1 * GB, 1e9) for i in range(2)])
    enc = ModuleSpec("enc", "encoder", "text", 1_000)
    lm = ModuleSpec("lm", "head", "task", 2_000, generative=True,
                    kv_bytes_per_token=64)
    return (Deployment(cluster)
            .add_model(ModelSpec("chat", "chat", (enc,), lm))
            .add_model(ModelSpec("summarize", "sum", (enc,), lm))
            .plan("greedy"))


def test_verify_model_check_reports_clean():
    diags = _dep().verify(model_check=True)
    codes = [d.code for d in diags]
    assert "modelcheck/clean" in codes
    assert not errors(diags)


def test_scenario_from_deployment_shares_modules():
    cfg = mc.scenario_from_deployment(_dep())
    assert {m.name for m in cfg.models} == {"chat", "summarize"}
    decoders = {m.decoder for m in cfg.models}
    assert decoders == {"lm"}          # shared decoder survives derivation
    res = mc.check(cfg)
    assert res.ok and res.complete
