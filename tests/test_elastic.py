"""Elasticity & straggler mitigation."""

import pytest

from repro.training.elastic import (
    ElasticTopology, Redispatcher, StragglerTracker,
)


def test_topology_detects_change():
    topo = ElasticTopology(hosts={"a", "b", "c"})
    assert not topo.update({"a", "b", "c"})
    assert topo.update({"a", "b"})          # node c died
    assert topo.generation == 1
    assert topo.update({"a", "b", "d"})     # node d joined
    assert topo.data_shards() == ["a", "b", "d"]


def test_straggler_filtered():
    t = StragglerTracker(threshold=2.0)
    for _ in range(5):
        t.record("fast1", 1.0)
        t.record("fast2", 1.1)
        t.record("slow", 10.0)
    assert t.is_straggler("slow")
    assert t.healthy(["fast1", "fast2", "slow"]) == ["fast1", "fast2"]


def test_redispatch_fails_over():
    t = StragglerTracker()
    r = Redispatcher(t)
    calls = []

    def run_on(dev):
        calls.append(dev)
        if dev == "bad":
            raise RuntimeError("device lost")
        return f"ok@{dev}"

    t.record("bad", 0.1)    # looks fastest
    t.record("good", 1.0)
    out, dev = r.call("vit", ["bad", "good"], run_on)
    assert out == "ok@good" and dev == "good"
    assert calls == ["bad", "good"]


def test_redispatch_all_fail():
    r = Redispatcher(StragglerTracker())
    with pytest.raises(RuntimeError):
        r.call("m", ["x"], lambda d: (_ for _ in ()).throw(ValueError()))


def test_elastic_replan_integration():
    """Pool shrinks -> replan keeps service feasible with migrations."""
    from repro.core.module import ModelSpec, ModuleSpec
    from repro.core.placement import greedy_place, replan
    from repro.core.cluster import ClusterSpec, DeviceSpec

    enc = ModuleSpec("e", "encoder", "vision", 50, flops_per_query=1e9)
    head = ModuleSpec("h", "head", "task", 10, flops_per_query=1e8)
    m = ModelSpec("m", "t", (enc,), head)
    c1 = ClusterSpec(devices=[DeviceSpec("a", 200, 2e9),
                              DeviceSpec("b", 200, 1e9)])
    pl1 = greedy_place([m], c1)
    c2 = c1.without("a")
    pl2, migrations = replan([m], c1, c2, pl1)
    assert pl2.feasible
    assert all(dev == "b" for _, dev in migrations)
