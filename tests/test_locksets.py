"""Interprocedural lockset race detector: the serving tree must analyze
clean, seeded lock-removal and lock-order mutations must be caught, and
the races it found (and we fixed) in ``serving/`` must stay fixed —
each regression test replays its static counterexample by reintroducing
the bug and asserting the detector reports it."""

import textwrap
from pathlib import Path

import pytest

import repro.serving as serving
from repro.analysis import locksets as ls
from repro.analysis.diagnostics import Severity

pytestmark = pytest.mark.analysis


def _codes(report):
    return [d.code for d in report.diagnostics]


def _serving_sources():
    root = Path(serving.__file__).parent
    return {f: (root / f).read_text()
            for f in ("scheduler.py", "decode.py", "kvcache.py",
                      "engine.py")}


# ---- the tree is clean --------------------------------------------------

def test_serving_tree_is_lockset_clean():
    rep = ls.lint_serving_locksets()
    assert rep.diagnostics == [], [d.format() for d in rep.diagnostics]
    assert rep.contexts > 20 and rep.accesses > 100


def test_self_test_is_all_clear():
    diags = ls.self_test()
    assert diags
    assert all(d.severity == Severity.INFO for d in diags), \
        [d.format() for d in diags]
    codes = [d.code for d in diags]
    assert codes.count("locksets/mutation-caught") >= 2


# ---- seeded mutations on the real tree ----------------------------------

def _analyze_with(mutated: dict[str, str]):
    srcs = _serving_sources()
    srcs.update(mutated)
    return ls.analyze_sources(sorted(srcs.items()))


def test_strip_lock_must_bite():
    with pytest.raises(ValueError, match="no lock"):
        ls.strip_lock("class A:\n    def f(self):\n        pass\n",
                      "A", "f")


@pytest.mark.parametrize("cls,method,attr", [
    ("DecodeStream", "submit", "waiting"),
    ("DecodeStream", "stats_dict", "live"),
    ("ServeScheduler", "_enqueue", "queues"),
])
def test_removed_lock_is_detected(cls, method, attr):
    fname = ("decode.py" if cls == "DecodeStream" else "scheduler.py")
    src = _serving_sources()[fname]
    rep = _analyze_with({fname: ls.strip_lock(src, cls, method)})
    hits = [d for d in rep.diagnostics
            if d.code in ("locksets/unlocked-write",
                          "locksets/unlocked-read",
                          "locksets/inconsistent-locks")
            and f"{cls}.{method}" in d.message]
    assert hits, [d.format() for d in rep.diagnostics]
    assert any(attr in d.message for d in hits)


def test_lock_order_cycle_is_detected():
    rep = ls.analyze_sources([("deadlock.py", ls._DEADLOCK_SNIPPET)])
    cycles = [d for d in rep.diagnostics
              if d.code == "locksets/lock-order-cycle"]
    assert cycles and "Left._lock" in cycles[0].message


# ---- regression: the races we fixed stay fixed --------------------------
# Each test replays the static counterexample the detector originally
# reported against serving/ by reverting the fix and asserting the
# finding comes back.

def test_route_snapshots_free_at_under_lock():
    """ServeScheduler._route used to read the live _free_at map while
    _charge wrote it under the lock from concurrent drains."""
    src = _serving_sources()["scheduler.py"]
    rep = _analyze_with(
        {"scheduler.py": ls.strip_lock(src, "ServeScheduler", "_route")})
    assert any("_free_at" in d.message and "_route" in d.message
               for d in rep.diagnostics), \
        [d.format() for d in rep.diagnostics]


def test_drain_snapshots_results_under_lock():
    """drain()/serve() used to hand out the live results dict while
    decode completions kept writing it."""
    src = _serving_sources()["scheduler.py"]
    rep = _analyze_with(
        {"scheduler.py": ls.strip_lock(src, "ServeScheduler", "drain")})
    assert any("results" in d.message for d in rep.diagnostics), \
        [d.format() for d in rep.diagnostics]


def test_encoder_batch_bookkeeping_is_locked():
    """_run_encoder_batch used to mutate in-flight bookkeeping (pending
    sets, encoder outputs) outside the scheduler lock."""
    src = _serving_sources()["scheduler.py"]
    rep = _analyze_with({"scheduler.py": ls.strip_lock(
        src, "ServeScheduler", "_run_encoder_batch")})
    assert any("_run_encoder_batch" in d.message
               for d in rep.diagnostics), \
        [d.format() for d in rep.diagnostics]


# ---- analysis semantics -------------------------------------------------

def test_pragma_suppresses_finding():
    src = textwrap.dedent("""
        import threading

        class Box:
            def __init__(self):
                self._lock = threading.Lock()
                self.items = []

            def put(self, x):
                with self._lock:
                    self.items.append(x)

            def peek(self):
                return self.items[-1]  # lockset: ignore
    """)
    rep = ls.analyze_sources([("box.py", src)])
    assert rep.diagnostics == [], [d.format() for d in rep.diagnostics]


def test_caller_locked_passive_class_is_clean():
    """A lock-free class mutated only under its caller's lock (the
    PagePool pattern) must not be flagged: entry points are public
    methods of lock-owning classes, so the passive class is analyzed
    only under the locksets its callers actually hold."""
    src = textwrap.dedent("""
        import threading

        class Pool:
            def __init__(self):
                self.free = [1, 2, 3]

            def take(self):
                return self.free.pop()

        class Owner:
            def __init__(self):
                self._lock = threading.Lock()
                self.pool = Pool()

            def grab(self):
                with self._lock:
                    return self.pool.take()
    """)
    rep = ls.analyze_sources([("pool.py", src)])
    assert rep.diagnostics == [], [d.format() for d in rep.diagnostics]


def test_syntax_error_reported_not_raised():
    rep = ls.analyze_sources([("bad.py", "def broken(:\n")])
    assert _codes(rep) == ["locksets/syntax-error"]
