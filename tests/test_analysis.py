"""repro.analysis: static plan verifier, Pallas kernel checker,
concurrency lint, and the Deployment.verify() pre-flight."""

import jax.numpy as jnp
import pytest

from repro.analysis import (
    PlanError, Severity, errors, format_report, verify_deployment,
)
from repro.analysis.concurrency_lint import lint_serving, lint_source
from repro.analysis.kernel_check import (
    ENTRY_POINTS, check_case, check_kernels, zoo_cases,
)
from repro.analysis.plan_check import check_plan
from repro.core.cluster import ClusterSpec, DeviceSpec
from repro.core.module import ModelSpec, ModuleSpec
from repro.core.placement import Placement
from repro.s2m3 import Deployment

MB = 1024**2
GB = 1024**3


def _codes(diags):
    return {d.code for d in diags}


def _cluster(n=3, cap=1 * GB, links=None):
    return ClusterSpec(
        devices=[DeviceSpec(f"d{i}", cap, 1e9) for i in range(n)],
        links=links or {})


def _specs():
    vis = ModuleSpec("vis-enc", "encoder", "vision", 60_000,
                     flops_per_query=2e6)
    txt = ModuleSpec("txt-enc", "encoder", "text", 50_000,
                     flops_per_query=1e6)
    cos = ModuleSpec("cos-head", "head", "task", 1_000)
    cls = ModuleSpec("cls-head", "head", "task", 1_000)
    retrieval = ModelSpec("retrieval", "retrieval", (vis, txt), cos)
    classify = ModelSpec("classify", "classification", (vis,), cls)
    return vis, txt, cos, cls, retrieval, classify


def _builders():
    return {
        "vis-enc": lambda: (lambda p, x: x * p, jnp.float32(2.0)),
        "txt-enc": lambda: (lambda p, x: x + p, jnp.float32(1.0)),
        "cos-head": lambda: (
            lambda p, enc: enc["vision"] + enc["text"] + p, jnp.float32(0.0)),
        "cls-head": lambda: (lambda p, enc: enc["vision"] * p,
                             jnp.float32(3.0)),
    }


def _dep(materialize=False):
    *_, retrieval, classify = _specs()
    dep = (Deployment(_cluster())
           .add_model(retrieval, _builders())
           .add_model(classify)
           .plan("greedy", routing="paper"))
    if materialize:
        dep.materialize()
    return dep


# ---- plan verifier ------------------------------------------------------

def test_clean_plan_verifies_clean():
    dep = _dep()
    diags = dep.verify()
    assert errors(diags) == [], format_report(diags)


def test_memory_overflow_rejected_statically():
    """Acceptance (a): a plan whose device ledger exceeds capacity is
    rejected by name, not by a mid-serve OOM."""
    dep = _dep()
    dep.placement.module_bytes["vis-enc"] = 100 * GB   # ledger drift
    diags = dep.verify()
    assert "plan/memory-overflow" in _codes(errors(diags))
    with pytest.raises(PlanError, match="plan/memory-overflow"):
        dep.materialize()


def test_unmapped_module_rejected_statically():
    """Acceptance (b): a module the plan never assigned is a named
    diagnostic at verify time — not a runtime KeyError."""
    dep = _dep()
    del dep.placement.assignment["txt-enc"]
    diags = dep.verify()
    errs = errors(diags)
    assert "plan/unmapped-module" in _codes(errs)
    assert any(d.entity == "txt-enc" for d in errs)
    with pytest.raises(PlanError, match="unmapped-module"):
        dep.materialize()


def test_sharing_collision_rejected_statically():
    """Acceptance (c): one signature shared across tasks with
    incompatible specs is a sharing-legality error."""
    enc_a = ModuleSpec("shared-enc", "encoder", "vision", 10_000,
                       output_bytes=512)
    enc_b = ModuleSpec("shared-enc", "encoder", "vision", 99_000,
                       output_bytes=2048)
    m1 = ModelSpec("vqa", "vqa", (enc_a,),
                   ModuleSpec("h1", "head", "task", 10))
    m2 = ModelSpec("cap", "captioning", (enc_b,),
                   ModuleSpec("h2", "head", "task", 10))
    pl = Placement(assignment={"shared-enc": ["d0"], "h1": ["d0"],
                               "h2": ["d1"]})
    diags = check_plan(pl, _cluster(), [m1, m2])
    hits = [d for d in errors(diags) if d.code == "plan/signature-collision"]
    assert hits and hits[0].entity == "shared-enc"
    assert "n_params" in hits[0].message

    # the same check through verify(): model drift injected behind the
    # registry's admission-time guard
    dep = Deployment(_cluster()).add_model(m1)
    dep.plan("greedy")
    dep.registry._models["cap"] = m2
    assert "plan/signature-collision" in _codes(errors(dep.verify()))


def test_dependency_cycle_detected():
    a = ModuleSpec("mod-a", "encoder", "vision", 10)
    b_head = ModuleSpec("mod-b", "head", "task", 10)
    b_enc = ModuleSpec("mod-b", "encoder", "vision", 10)
    a_head = ModuleSpec("mod-a", "head", "task", 10)
    m1 = ModelSpec("m1", "t1", (a,), b_head)       # a -> b
    m2 = ModelSpec("m2", "t2", (b_enc,), a_head)   # b -> a
    pl = Placement(assignment={"mod-a": ["d0"], "mod-b": ["d1"]})
    diags = check_plan(pl, _cluster(), [m1, m2])
    assert "plan/dependency-cycle" in _codes(errors(diags))


def test_unreachable_route_detected():
    vis, txt, cos, _, retrieval, _ = _specs()
    links = {("d0", "d1"): (0.0, 0.0)}            # explicit partition
    pl = Placement(assignment={"vis-enc": ["d0"], "txt-enc": ["d1"],
                               "cos-head": ["d1"]})
    diags = check_plan(pl, _cluster(2, links=links), [retrieval])
    hits = [d for d in errors(diags) if d.code == "plan/unreachable-route"]
    assert hits and hits[0].entity == "d0"        # vis-enc cannot reach d1


def test_unknown_device_and_duplicate_replica():
    vis, txt, cos, _, retrieval, _ = _specs()
    pl = Placement(assignment={"vis-enc": ["ghost"], "txt-enc": ["d0", "d0"],
                               "cos-head": ["d1"]})
    diags = check_plan(pl, _cluster(2), [retrieval])
    assert "plan/unknown-device" in _codes(errors(diags))
    assert "plan/duplicate-replica" in _codes(diags)


def test_infeasible_plan_reported():
    *_, retrieval, _classify = _specs()
    dep = Deployment(_cluster(1, cap=1))          # 1-byte device
    dep.add_model(retrieval).plan("greedy")
    assert "plan/infeasible" in _codes(errors(dep.verify()))


def test_unknown_plan_option_warned():
    dep = _dep()
    dep._plan_opts = {"replicte": True}           # typo'd 'replicate'
    diags = dep.verify()
    hits = [d for d in diags if d.code == "plan/unknown-option"]
    assert hits and hits[0].severity == Severity.WARNING
    assert hits[0].entity == "replicte"


def test_evict_keeps_refcounts_consistent():
    """After evicting one task, verify() stays clean and shared-module
    refcounts match the surviving placement."""
    dep = _dep(materialize=True)
    freed = dep.evict("classify")
    assert "cls-head" in freed and "vis-enc" not in freed
    diags = dep.verify()
    assert errors(diags) == [], format_report(diags)
    assert dep.registry.refcount("vis-enc") == 1
    assert "vis-enc" in dep.placement.assignment
    assert "cls-head" not in dep.placement.assignment


def test_stale_assignment_warned():
    dep = _dep()
    dep.registry.remove_model("classify")         # bypass Deployment.evict
    diags = dep.verify()
    assert "plan/stale-assignment" in _codes(diags)


# ---- PlanError (satellite: structured engine error) ---------------------

def test_plan_error_is_structured_keyerror():
    err = PlanError("module 'x' unmapped", module="x",
                    requested=("a",), available=("b", "c"))
    assert isinstance(err, KeyError)
    assert err.module == "x" and err.available == ("b", "c")
    assert str(err) == "module 'x' unmapped"


def test_engine_module_hosts_raises_plan_error():
    dep = _dep(materialize=True)
    dep.placement.assignment["vis-enc"] = ["ghost-dev"]
    dep.engine.placement = dep.placement
    with pytest.raises(PlanError, match="ghost-dev") as ei:
        dep.engine.module_hosts("vis-enc")
    assert ei.value.module == "vis-enc"
    assert ei.value.requested == ("ghost-dev",)
    assert "d0" in ei.value.available


# ---- scheduler stats schema (satellite) ---------------------------------

def test_stats_dict_stable_schema_before_serving():
    from repro.serving.scheduler import STAT_KEYS, ServeScheduler

    dep = _dep(materialize=True)
    sched = ServeScheduler(dep.engine)
    sd = sched.stats_dict()
    assert set(sd) == set(dep.registry.modules)    # every deployed module
    for name, row in sd.items():
        assert set(row) == set(STAT_KEYS)
        assert row["calls"] == 0 and row["stages"] == 0
        assert row["module"] == name


# ---- kernel checker -----------------------------------------------------

def test_zoo_kernel_sweep_is_error_free():
    cases = zoo_cases()
    assert {c.entry for c in cases} == set(ENTRY_POINTS)
    diags = check_kernels()
    assert errors(diags) == [], format_report(diags)
    # xlstm's resident R + gate tile genuinely exceeds 16 MiB: the sweep
    # must say so (as a warning, since it still executes)
    assert any(d.code == "kernel/vmem-budget" for d in diags)


def test_block_divisibility_rejected():
    from repro.kernels.plan import KernelPlanError, flash_block_plan

    with pytest.raises(KernelPlanError, match="block_q"):
        flash_block_plan(1, 300, 8, 64, 300, 8, 256, 256, "bfloat16")
    with pytest.raises(KernelPlanError, match="multiple of kv heads"):
        flash_block_plan(1, 256, 6, 64, 256, 4, 256, 256, "bfloat16")


def test_kernel_wrapper_raises_plan_error_at_trace_time():
    import functools

    import jax

    from repro.kernels import ops
    from repro.kernels.plan import KernelPlanError

    q = jax.ShapeDtypeStruct((1, 300, 8, 64), "float32")
    kv = jax.ShapeDtypeStruct((1, 300, 8, 64), "float32")
    with pytest.raises(KernelPlanError, match="block_q"):
        jax.eval_shape(functools.partial(ops.flash_attention,
                                         block_q=256, block_k=256),
                       q, kv, kv)


def test_check_case_flags_bad_geometry_and_drift():
    import jax

    from repro.analysis.kernel_check import KernelCase, _flash_case

    bad = _flash_case("bad/indivisible", B=1, S=300, H=8, D=64, T=300, K=8)
    diags = check_case(bad)
    assert _codes(errors(diags)) == {"kernel/block-divisibility"}

    drifted = KernelCase(
        "drift/flash", "flash_attention",
        (jax.ShapeDtypeStruct((1, 256, 8, 64), "bfloat16"),
         jax.ShapeDtypeStruct((1, 256, 8, 64), "bfloat16"),
         jax.ShapeDtypeStruct((1, 256, 8, 64), "bfloat16")),
        expected_fn=lambda: jax.ShapeDtypeStruct((1, 256, 8, 128),
                                                 "bfloat16"))
    diags = check_case(drifted)
    assert "kernel/shape-drift" in _codes(errors(diags))


def test_vmem_budget_configurable():
    diags = check_kernels(vmem_budget=1024)       # 1 KiB: everything over
    warned = {d.entity for d in diags if d.code == "kernel/vmem-budget"}
    assert len(warned) == len(zoo_cases())


# ---- concurrency lint ---------------------------------------------------

_LOCKED_CLASS = '''
import threading, jax
class Sched:
    def __init__(self):
        self._lock = threading.Lock()
        self.queue = []
    def good(self):
        with self._lock:
            self.queue.append(1)
    def {body}
'''


def test_lint_unlocked_mutation():
    src = _LOCKED_CLASS.format(body="bad(self):\n        self.queue.append(2)")
    diags = lint_source(src, "sched.py")
    hits = [d for d in diags if d.code == "concurrency/unlocked-mutation"]
    assert len(hits) == 1 and hits[0].severity == Severity.ERROR
    assert "sched.py:" in hits[0].entity


def test_lint_dispatch_under_lock():
    src = _LOCKED_CLASS.format(
        body="bad(self, x):\n        with self._lock:\n"
             "            return jax.block_until_ready(x)")
    diags = lint_source(src, "sched.py")
    assert any(d.code == "concurrency/dispatch-under-lock"
               and d.severity == Severity.WARNING for d in diags)


def test_lint_registry_mutation_in_batch_path():
    src = '''
class Sched:
    def step(self):
        self._service("m")
    def _service(self, m):
        self._grow(m)
    def _grow(self, m):
        self.engine.registry.add_model(m)
'''
    diags = lint_source(src, "sched.py")
    hits = [d for d in diags
            if d.code == "concurrency/registry-mutation-in-batch-path"]
    assert len(hits) == 1 and "add_model" in hits[0].message


def test_lint_ignores_unguarded_only_attrs():
    # attrs never mutated under a lock are not flagged (no discipline
    # was declared for them)
    src = _LOCKED_CLASS.format(body="ok(self):\n        self.other = 1")
    assert not [d for d in lint_source(src, "s.py")
                if d.code == "concurrency/unlocked-mutation"]


def test_serving_layer_lints_clean():
    diags = lint_serving()
    assert errors(diags) == [], format_report(diags)


def test_lint_unlocked_allocator_call():
    """Allocator mutation paths (pool.alloc/extend/free, rows.release)
    outside the lock are ERRORs in lock-bearing classes."""
    src = _LOCKED_CLASS.format(
        body="bad(self, rid):\n        self.pool.free(rid)")
    hits = [d for d in lint_source(src, "sched.py")
            if d.code == "concurrency/unlocked-allocator-call"]
    assert len(hits) == 1 and hits[0].severity == Severity.ERROR
    assert "free" in hits[0].message


def test_lint_allocator_call_under_lock_ok():
    src = _LOCKED_CLASS.format(
        body="ok(self, rid):\n        with self._lock:\n"
             "            self.pool.extend(rid, 4)")
    assert not [d for d in lint_source(src, "s.py")
                if d.code == "concurrency/unlocked-allocator-call"]


def test_lint_allocator_call_in_init_exempt():
    # __init__ runs before the object is shared; no lock required
    src = '''
import threading
class Stream:
    def __init__(self):
        self._lock = threading.Lock()
        self.pool = object()
        self.pool.alloc("dummy", 1)
    def tick(self):
        with self._lock:
            self.pool.alloc("x", 1)
'''
    assert not [d for d in lint_source(src, "s.py")
                if d.code == "concurrency/unlocked-allocator-call"]


def test_lint_allocator_rule_needs_a_lock():
    # classes with no lock attr declare no discipline -> not flagged
    src = '''
class Free:
    def go(self):
        self.pool.alloc("x", 1)
'''
    assert not [d for d in lint_source(src, "s.py")
                if d.code == "concurrency/unlocked-allocator-call"]


# ---- paged-KV page budget ------------------------------------------------

def _gen_dep(kv_bytes, cap=1 * GB):
    head = ModuleSpec("lm-head", "head", "task", 1_000, generative=True,
                      kv_bytes_per_token=kv_bytes)
    model = ModelSpec("chat", "chat", (), head)
    dep = (Deployment(_cluster(n=1, cap=cap))
           .add_model(model, {"lm-head": lambda: (lambda p, e: p,
                                                  jnp.float32(0.0))})
           .plan("greedy"))
    return dep


def test_page_budget_overflow_is_error():
    from repro.analysis.plan_check import check_page_budget

    dep = _gen_dep(kv_bytes=1 * MB, cap=1 * GB)
    diags = check_page_budget(dep.placement, dep.cluster, dep.models,
                              decode_pages=64, page_size=16)
    errs = errors(diags)
    assert "plan/page-budget" in _codes(errs)     # 1 GiB pool vs 1 GiB cap
    assert any(d.entity == "lm-head" for d in errs)
    # a pool that fits is clean
    assert not check_page_budget(dep.placement, dep.cluster, dep.models,
                                 decode_pages=4, page_size=16)


def test_page_budget_unspecified_kv_is_warning():
    from repro.analysis.plan_check import check_page_budget

    dep = _gen_dep(kv_bytes=0)
    diags = check_page_budget(dep.placement, dep.cluster, dep.models,
                              decode_pages=64, page_size=16)
    assert errors(diags) == []
    assert "plan/kv-unspecified" in _codes(diags)


def test_serve_preflight_rejects_oversized_page_pool():
    dep = _gen_dep(kv_bytes=1 * MB, cap=1 * GB)
    diags = verify_deployment(dep, decode_pages=64, page_size=16)
    assert "plan/page-budget" in _codes(errors(diags))
    dep.materialize()
    with pytest.raises(PlanError, match="page-budget"):
        dep.serve([], decode_pages=64, page_size=16)


# ---- CLI ----------------------------------------------------------------

@pytest.mark.analysis
def test_cli_self_mode_exits_clean(capsys):
    from repro.analysis.__main__ import main

    assert main(["--self"]) == 0
    out = capsys.readouterr().out
    assert "error(s)" in out


def test_cli_fails_on_error_findings(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(_LOCKED_CLASS.format(
        body="bad(self):\n        self.queue.append(2)"))
    from repro.analysis.__main__ import main

    assert main([str(bad), "--kernels"]) == 1


# ---- verify_deployment convenience --------------------------------------

def test_verify_deployment_with_kernels():
    dep = _dep()
    diags = verify_deployment(dep, kernels=True)
    assert errors(diags) == [], format_report(diags)
    assert any(d.code == "kernel/summary" for d in diags)


# ---- obs/raw-clock-call -------------------------------------------------

_CLOCKY = """
import time

def stamp():
    return time.time()

def tick():
    return time.monotonic()

def ok():
    return time.perf_counter()
"""


def test_raw_clock_flagged_in_serving_and_obs():
    for scoped in ("src/repro/serving/x.py", "src/repro/obs/x.py"):
        diags = lint_source(_CLOCKY, filename=scoped)
        codes = [d.code for d in diags]
        assert codes == ["obs/raw-clock-call"] * 2, (scoped, codes)
        # perf_counter (the injected-clock backend) is not flagged
        assert all("perf_counter" not in d.message for d in diags)


def test_raw_clock_ignored_outside_scoped_layers():
    assert lint_source(_CLOCKY, filename="src/repro/launch/train.py") == []


def test_serving_and_obs_trees_have_no_raw_clocks():
    import repro.obs as obs
    from pathlib import Path
    from repro.analysis.concurrency_lint import lint_paths

    diags = lint_paths([Path(obs.__file__).parent])
    diags += lint_serving()
    assert [d for d in diags if d.code == "obs/raw-clock-call"] == []
