"""HLO cost analyzer: exact dot flops, while-loop trip multiplication."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.hlo_cost import HloCost, analyze


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops_exact():
    m, k, n = 32, 64, 16
    a = jnp.zeros((m, k))
    b = jnp.zeros((k, n))
    rep = analyze(_compiled_text(lambda a, b: a @ b, a, b))
    assert rep.flops == 2 * m * k * n


def test_scan_multiplies_by_trip_count():
    k = 8
    w = jnp.zeros((k, 16, 16))
    x = jnp.zeros((4, 16))

    def f(w, x):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y

    rep = analyze(_compiled_text(f, w, x))
    expect = k * 2 * 4 * 16 * 16
    # allow small deviation from fusion rewrites, but the trip count must
    # be applied (a scan-once count would be 8x smaller)
    assert expect * 0.9 <= rep.flops <= expect * 1.2, rep.flops


def test_nested_scan_trip_product():
    w = jnp.zeros((3, 4, 8, 8))
    x = jnp.zeros((2, 8))

    def f(w, x):
        def outer(c, wo):
            def inner(ci, wi):
                return ci @ wi, None
            c2, _ = jax.lax.scan(inner, c, wo)
            return c2, None
        y, _ = jax.lax.scan(outer, x, w)
        return y

    rep = analyze(_compiled_text(f, w, x))
    expect = 3 * 4 * 2 * 2 * 8 * 8
    assert expect * 0.9 <= rep.flops <= expect * 1.2, rep.flops


def test_bytes_positive_and_scale_with_input():
    small = analyze(_compiled_text(lambda x: (x * 2).sum(), jnp.zeros((128,))))
    big = analyze(_compiled_text(lambda x: (x * 2).sum(), jnp.zeros((4096,))))
    assert big.bytes > small.bytes > 0


def test_no_collectives_on_single_device():
    rep = analyze(_compiled_text(lambda x: x @ x, jnp.zeros((8, 8))))
    assert rep.collective_bytes == 0
