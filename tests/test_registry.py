"""Module sharing registry + paper Table X arithmetic."""

import pytest

from repro.core.module import ModelSpec, ModuleSpec, distinct_modules
from repro.core.registry import ModuleRegistry
from repro.core.zoo import paper_zoo


def _m(name, n=10):
    return ModuleSpec(name, "encoder", "vision", n)


def _model(name, *mods, head=None):
    return ModelSpec(name, "t", tuple(mods), head or ModuleSpec(
        f"{name}-head", "head", "task", 1))


def test_add_returns_only_new_modules():
    reg = ModuleRegistry()
    shared = _m("vit")
    new1 = reg.add_model(_model("m1", shared))
    new2 = reg.add_model(_model("m2", shared))
    assert {m.name for m in new1} == {"vit", "m1-head"}
    assert {m.name for m in new2} == {"m2-head"}
    assert reg.refcount("vit") == 2


def test_remove_frees_only_unreferenced():
    reg = ModuleRegistry()
    shared = _m("vit")
    reg.add_model(_model("m1", shared))
    reg.add_model(_model("m2", shared))
    freed = reg.remove_model("m1")
    assert {m.name for m in freed} == {"m1-head"}
    freed = reg.remove_model("m2")
    assert {m.name for m in freed} == {"vit", "m2-head"}


def test_signature_collision_rejected():
    reg = ModuleRegistry()
    reg.add_model(_model("m1", _m("vit", 10)))
    with pytest.raises(ValueError):
        reg.add_model(_model("m2", _m("vit", 99)))   # same name, diff spec
    with pytest.raises(ValueError):
        distinct_modules([_model("a", _m("x", 1)), _model("b", _m("x", 2))])


def test_paper_table_x_sharing_savings():
    """Table X: 4 tasks share ViT-B/16 + CLIP TRF -> 61.5% saving."""
    zoo = paper_zoo()
    reg = ModuleRegistry()
    for name in ("clip-vit-b/16", "encoder-only-vqa-s", "alignment-vit-b",
                 "clip-cls-vit-b/16"):
        reg.add_model(zoo[name])
    saving = reg.sharing_savings()
    assert 0.58 <= saving <= 0.65, saving    # paper: 61.5%


def test_paper_split_savings_table_vi():
    """Table VI: per-model max-module saving, e.g. CLIP RN50 ~50%."""
    zoo = paper_zoo()
    rn50 = zoo["clip-resnet-50"]
    saving = 1 - rn50.max_module_bytes / rn50.total_bytes
    assert 0.45 <= saving <= 0.55            # paper: -50%
    vitb16 = zoo["clip-vit-b/16"]
    saving = 1 - vitb16.max_module_bytes / vitb16.total_bytes
    assert 0.25 <= saving <= 0.35            # paper: -31%
