"""Observability layer: span/tracer core, per-request trace trees
through the serving stack, the metrics registry, stats_dict()
compatibility, drift reports, and the instrument-lock lint."""

import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import get_config
from repro.core.cluster import ClusterSpec, DeviceSpec
from repro.core.module import ModelSpec, ModuleSpec
from repro.models.api import build_model
from repro.obs import (
    Counter, Gauge, Histogram, MetricsRegistry, Span, Trace, Tracer,
    slo_summary,
)
from repro.s2m3 import Deployment, Request

GB = 1024**3


# ---- tracer core --------------------------------------------------------

def _fake_clock(start=0.0, step=1.0):
    t = [start]

    def clock():
        t[0] += step
        return t[0]

    return clock


def test_span_iterates_as_legacy_timeline_tuple():
    s = Span("mini-vit", "encode", 1.0, 2.5, rid=7)
    mod, phase, t0, t1 = s
    assert (mod, phase, t0, t1) == ("mini-vit", "encode", 1.0, 2.5)
    assert s.dur == 1.5 and not s.open


def test_tracer_builds_parented_tree_with_injected_clock():
    tr = Tracer(clock=_fake_clock())
    root = tr.begin("request", "request", rid=3, model="vqa")
    child = tr.begin("enc", "encode", rid=3, parent=root)
    tr.end(child)
    tr.end(root)
    trace = tr.trace
    assert trace.validate(3) == []
    tree = trace.tree(3)
    assert tree.name == "request" and tree.attrs["model"] == "vqa"
    kids = trace.children(tree.sid)
    assert [k.phase for k in kids] == ["encode"]
    # injected clock: deterministic timestamps
    assert (tree.t0, kids[0].t0, kids[0].t1, tree.t1) == (1.0, 2.0, 3.0, 4.0)


def test_tracer_end_is_idempotent_and_rejects_bad_sid():
    tr = Tracer(clock=_fake_clock())
    sid = tr.begin("m", "head", rid=0)
    first = tr.end(sid).t1
    assert tr.end(sid).t1 == first          # re-end keeps the first t1
    with pytest.raises(ValueError, match="invalid span id"):
        tr.end(-1)


def test_validate_flags_malformed_trees():
    trace = Trace([
        Span("request", "request", 0.0, 10.0, rid=1, sid=0),
        Span("m", "encode", 2.0, 12.0, rid=1, sid=1, parent=0),
        Span("m", "wait", 1.0, 2.0, rid=1, sid=2, parent=99),
        Span("m", "head", 3.0, None, rid=1, sid=3, parent=0),
    ])
    found = "\n".join(trace.validate(1))
    for needle in ("escapes parent", "orphan", "unclosed"):
        assert needle in found


def test_chrome_trace_export_shape(tmp_path):
    tr = Tracer(clock=_fake_clock())
    root = tr.begin("request", "request", rid=5)
    tr.record("enc", "encode", 2.0, 3.0, rid=5, parent=root, batch=2)
    tr.end(root)
    out = tmp_path / "trace.json"
    tr.trace.save(out)
    data = json.loads(out.read_text())
    events = data["traceEvents"]
    assert len(events) == 2
    for ev in events:
        assert ev["ph"] == "X" and ev["tid"] == 5
    enc = next(e for e in events if e["name"] == "enc:encode")
    assert enc["ts"] == 2e6 and enc["dur"] == 1e6     # seconds -> us
    assert enc["args"]["batch"] == 2 and "parent" in enc["args"]


# ---- metrics registry ---------------------------------------------------

def test_registry_get_or_create_and_kind_collision():
    reg = MetricsRegistry()
    c = reg.counter("x", module="m")
    assert c is reg.counter("x", module="m")          # same labels: same
    assert c is not reg.counter("x", module="n")      # new labels: new
    assert isinstance(reg.gauge("g"), Gauge)
    assert isinstance(reg.histogram("h"), Histogram)
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("x", module="m")


def test_counter_rejects_negative_and_histogram_percentiles():
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        reg.counter("c").inc(-1)
    h = reg.histogram("lat")
    for v in range(1, 101):
        h.observe(float(v))
    assert h.count == 100 and h.percentile(50) in (50.0, 51.0)
    assert h.percentile(99) == 99.0 and h.max == 100.0
    assert h.percentile(0) == 1.0 and h.percentile(100) == 100.0


def test_registry_thread_safety_under_concurrent_increments():
    reg = MetricsRegistry()
    n_threads, n_iter = 8, 2500

    def work():
        c = reg.counter("hits", worker="shared")
        g = reg.gauge("depth")
        h = reg.histogram("lat")
        for i in range(n_iter):
            c.inc()
            g.track_max(i)
            h.observe(float(i))

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.value("hits", worker="shared") == n_threads * n_iter
    assert reg.histogram("lat").count == n_threads * n_iter
    assert reg.gauge("depth").value == n_iter - 1


def test_metric_lint_fires_on_unlocked_instrument_mutation():
    from repro.analysis.concurrency_lint import lint_source

    bad = """
import threading

class RacyGauge:
    kind = "gauge"

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0

    def set(self, v):
        self._value = v
"""
    diags = lint_source(bad, "<bad>")
    assert any(d.code == "obs/unlocked-metric-mutation" for d in diags)
    # the shipped instruments are clean
    from pathlib import Path

    import repro.obs
    from repro.analysis.concurrency_lint import lint_paths
    from repro.analysis.diagnostics import errors

    assert errors(lint_paths([Path(repro.obs.__file__).parent])) == []


# ---- serving integration: the acceptance fixture ------------------------

@pytest.fixture(scope="module")
def vlm_deployment():
    """Two generative tasks ("caption" + "ocr") sharing a vision encoder
    AND a generative VLM head — every span phase of the serving stack
    (admission/batch/encode/prefill/decode ticks) appears in one trace."""
    cfg = get_config("internvl2-1b", smoke=True)
    bundle = build_model(cfg, compute_dtype=jnp.float32)
    params = bundle.init(jax.random.PRNGKey(0))
    d = cfg.d_model
    enc = ModuleSpec("pix-enc", "encoder", "vision", 4 * d * d,
                     flops_per_query=2e5)
    head = ModuleSpec("vlm-head", "head", "task", 100_000, generative=True,
                      flops_per_query=4e5, kv_bytes_per_token=1024)
    w = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (d, d))
    builders = {
        "pix-enc": lambda: (lambda p, x: jnp.tanh(x @ p), w),
        "vlm-head": lambda: (bundle, params),
    }
    cluster = ClusterSpec(devices=[DeviceSpec(f"dev{i}", GB, 1e9)
                                   for i in range(2)])
    dep = (Deployment(cluster)
           .add_model(ModelSpec("caption", "captioning", (enc,), head),
                      builders)
           .add_model(ModelSpec("ocr", "ocr", (enc,), head))
           .plan("greedy").materialize())
    return dep, cfg


def _vlm_workload(cfg, n=4):
    img = 0.1 * np.random.default_rng(0).standard_normal(
        (cfg.n_image_tokens, cfg.d_model)).astype(np.float32)
    return [Request(rid=i, model=("caption" if i % 2 == 0 else "ocr"),
                    source="dev0", prompt=(1, 2, 3), max_new_tokens=3 + i,
                    inputs={"vision": img}, slo_deadline=30.0)
            for i in range(n)]


_SERVE_KW = dict(decode_rows=2, page_size=8, max_seq_len=64,
                 decode_pages=33)


def test_serve_trace_is_one_contiguous_tree_per_request(vlm_deployment,
                                                        tmp_path):
    """Acceptance: dep.serve() over a two-task shared-encoder workload
    exports Chrome-trace JSON whose spans for one rid form a contiguous
    tree: admission -> batch -> encode -> prefill -> decode ticks."""
    dep, cfg = vlm_deployment
    reqs = _vlm_workload(cfg, n=4)
    results = dep.serve(reqs, **_SERVE_KW)
    trace = dep.trace()
    assert trace.validate() == []                  # every tree contiguous
    assert trace.rids() == [q.rid for q in reqs]

    for q in reqs:
        root = trace.tree(q.rid)                   # exactly one root
        assert root.name == "request"
        assert root.attrs["model"] == q.model
        phases = {s.phase for s in trace.spans_for(q.rid)}
        assert {"request", "admission", "batch", "encode", "prefill",
                "decode", "decode_tick"} <= phases
        # decode ticks nest under the decode residency span
        decode = next(s for s in trace.spans_for(q.rid)
                      if s.phase == "decode")
        ticks = trace.children(decode.sid)
        assert ticks and all(t.phase == "decode_tick" for t in ticks)
        assert all(t.attrs["pages_live"] > 1 for t in ticks)
        assert all(t.attrs["rows"] >= 1 for t in ticks)
    # the shared encoder's spans carry cross-task batch composition
    enc_spans = [s for s in trace.spans
                 if s.name == "pix-enc" and s.phase == "encode"]
    assert any(s.attrs["cross_task"] and
               s.attrs["models"] == ["caption", "ocr"] for s in enc_spans)

    # chrome export: one "X" event per span, one track per rid
    out = tmp_path / "serve_trace.json"
    trace.save(out)
    events = json.loads(out.read_text())["traceEvents"]
    assert len(events) == len(trace)
    assert {e["tid"] for e in events} == {q.rid for q in reqs}

    # results still expose the legacy timeline tuples
    for r in results:
        assert any(stage == "decode" for _, stage, _, _ in r.timeline)


def test_scheduler_metrics_power_slo_summary(vlm_deployment):
    dep, cfg = vlm_deployment
    reqs = _vlm_workload(cfg, n=4)
    dep.serve(reqs, **_SERVE_KW)
    rows = {r["model"]: r for r in slo_summary(dep.scheduler)}
    assert set(rows) == {"caption", "ocr"}
    for row in rows.values():
        assert row["requests"] == 2
        assert row["p99_ms"] >= row["p50_ms"] > 0
        assert row["slo_requests"] == 2
        assert row["slo_attainment"] == 1.0        # 30 s deadline: trivial


def test_compare_reports_zero_divergence_and_module_ratios(vlm_deployment):
    """Acceptance: dep.compare() on the shared-encoder workload reports
    zero route divergences and a per-module latency ratio table."""
    dep, cfg = vlm_deployment
    reqs = _vlm_workload(cfg, n=4)
    report = dep.compare(reqs, **_SERVE_KW)
    assert report.n_requests == 4
    assert report.routes_checked >= 8              # enc + head per request
    assert report.n_route_divergences == 0
    assert set(report.modules) == {"pix-enc", "vlm-head"}
    for md in report.modules.values():
        assert md.predicted_s > 0 and md.measured_s > 0
        assert md.ratio > 0 and md.n > 0
    assert len(report.request_latency) == 4
    assert report.measured_mean_latency > 0
    assert report.queue_model_error >= 0
    text = report.summary()
    assert "0 divergence(s)" in text and "ratio" in text


def test_stats_dict_zeroed_schema_pre_serve_including_decode(
        vlm_deployment):
    """The registry-backed stats_dict() keeps the stable zeroed schema
    before any serving, for encoder rows AND decode-stream rows."""
    from repro.serving.scheduler import STAT_KEYS, ServeScheduler

    dep, _ = vlm_deployment
    sched = ServeScheduler(dep.engine)
    sd = sched.stats_dict()
    assert set(sd) == set(dep.registry.modules)
    for name, row in sd.items():
        assert set(row) == set(STAT_KEYS)
        assert row["module"] == name
        for key in ("calls", "stages", "max_batch", "cross_task_batches",
                    "max_depth"):
            assert row[key] == 0
        assert row["mean_occupancy"] == 0.0
    # a decode stream created pre-serve reports its keys, all zeroed
    stream = sched._ensure_stream("vlm-head")
    assert stream.decode_steps == 0 and stream.prefills == 0
    row = sched.stats_dict()["vlm-head"]
    for key in ("decode_steps", "decode_tokens", "prefills",
                "cross_task_decode_batches", "live_rows", "waiting"):
        assert row[key] == 0
    assert row["pages_live"] == 1                  # the dummy page
    assert sched.cross_task_batches == 0


def test_rejected_request_root_span_is_closed(vlm_deployment):
    from repro.serving.scheduler import (
        QueueFull, SchedulerConfig, ServeScheduler,
    )

    dep, cfg = vlm_deployment
    sched = ServeScheduler(dep.engine, config=SchedulerConfig(
        max_queue_depth=1, admission="reject", decode_rows=2, page_size=8,
        max_seq_len=64, decode_pages=33))
    reqs = _vlm_workload(cfg, n=4)
    with pytest.raises(QueueFull):
        for q in reqs:
            sched.submit(q)
    sched.drain()
    trace = sched.tracer.trace
    assert trace.validate() == []                  # rejects close cleanly
    rejected = [s for s in trace.spans
                if s.phase == "request" and s.attrs.get("rejected")]
    assert rejected and all(not s.open for s in rejected)


def test_pagepool_registers_occupancy_instruments(vlm_deployment):
    dep, cfg = vlm_deployment
    dep.serve(_vlm_workload(cfg, n=3), **_SERVE_KW)
    mt = dep.scheduler.metrics
    assert mt.value("pagepool.pages_live", module="vlm-head") == 1
    assert mt.value("pagepool.pages_peak", module="vlm-head") > 1
    assert mt.value("pagepool.page_allocs", module="vlm-head") > 0
    assert mt.value("pagepool.seq_frees", module="vlm-head") == 3
    # engine-lifetime counters tick independently of the scheduler's
    assert dep.engine.metrics.total("engine.decode_steps") > 0


def test_obs_self_test_passes():
    from repro.analysis.diagnostics import Severity
    from repro.obs.selftest import self_test

    diags = self_test()
    assert all(d.severity < Severity.ERROR for d in diags)


def test_obs_cli_self_test_exit_code():
    from repro.obs.__main__ import main

    assert main(["--self-test"]) == 0
